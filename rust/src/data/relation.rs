//! Columnar relations with optional tuple multiplicities and a
//! value-keyed row index for O(1) retraction.

use super::schema::{AttrType, Schema};
use super::value::{CatId, Value};
use crate::util::FxHashMap;

/// A typed column of values.
#[derive(Clone, Debug)]
pub enum Column {
    Int(Vec<i64>),
    Double(Vec<f64>),
    Cat(Vec<CatId>),
}

impl Column {
    /// Empty column of the given type.
    pub fn empty(ty: AttrType) -> Self {
        match ty {
            AttrType::Int => Column::Int(Vec::new()),
            AttrType::Double => Column::Double(Vec::new()),
            AttrType::Cat => Column::Cat(Vec::new()),
        }
    }

    /// Number of rows.
    pub fn len(&self) -> usize {
        match self {
            Column::Int(v) => v.len(),
            Column::Double(v) => v.len(),
            Column::Cat(v) => v.len(),
        }
    }

    /// True if the column has no rows.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Value at a row.
    #[inline]
    pub fn get(&self, row: usize) -> Value {
        match self {
            Column::Int(v) => Value::Int(v[row]),
            Column::Double(v) => Value::Double(v[row]),
            Column::Cat(v) => Value::Cat(v[row]),
        }
    }

    /// Join-key encoding at a row (panics for Double columns).
    #[inline]
    pub fn key_u64(&self, row: usize) -> u64 {
        match self {
            Column::Int(v) => v[row] as u64,
            Column::Cat(v) => v[row] as u64,
            Column::Double(_) => panic!("continuous attribute used as a join key"),
        }
    }

    fn push(&mut self, v: Value) {
        match (self, v) {
            (Column::Int(col), Value::Int(x)) => col.push(x),
            (Column::Double(col), Value::Double(x)) => col.push(x),
            (Column::Cat(col), Value::Cat(x)) => col.push(x),
            (col, v) => panic!("type mismatch pushing {v:?} into {col:?}"),
        }
    }
}

/// A named relation: a schema plus columns of equal length, and an optional
/// per-tuple weight vector (tuple multiplicity). Multiplicities arise from
/// quotient/grouped relations in the coreset construction; plain base
/// relations have weight 1 per tuple.
#[derive(Clone, Debug)]
pub struct Relation {
    pub name: String,
    pub schema: Schema,
    cols: Vec<Column>,
    weights: Option<Vec<f64>>,
    len: usize,
    /// Fully-retracted tuples still occupying storage (see `retract_row`).
    zero_rows: usize,
    /// Value-keyed row index: encoded tuple → row ids (oldest first),
    /// built lazily on the first retraction so insert-only workloads pay
    /// nothing. Makes `retract_row` O(1) in the relation size instead of
    /// a newest-first O(n) scan; `compact` drops it (row ids shift) and
    /// the next retraction rebuilds it.
    row_index: Option<FxHashMap<Vec<u64>, Vec<u32>>>,
}

/// Hash encoding of a full tuple for the value-keyed row index. Doubles
/// use their bit pattern with -0.0 normalized to 0.0 so the index agrees
/// with `Value` equality; candidates are still value-verified on hit, so
/// a cross-type key collision (e.g. `Int(5)` vs `Cat(5)`) cannot match.
fn encode_row_key(vals: &[Value]) -> Vec<u64> {
    vals.iter()
        .map(|v| match v {
            Value::Int(x) => *x as u64,
            Value::Cat(c) => *c as u64,
            Value::Double(x) => {
                let x = if *x == 0.0 { 0.0 } else { *x };
                x.to_bits()
            }
        })
        .collect()
}

impl Relation {
    /// Create an empty relation.
    pub fn new(name: &str, schema: Schema) -> Self {
        let cols = schema.attrs().iter().map(|a| Column::empty(a.ty)).collect();
        Relation {
            name: name.to_string(),
            schema,
            cols,
            weights: None,
            len: 0,
            zero_rows: 0,
            row_index: None,
        }
    }

    /// Number of tuples.
    pub fn n_rows(&self) -> usize {
        self.len
    }

    /// True if the relation is empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of attributes.
    pub fn n_cols(&self) -> usize {
        self.cols.len()
    }

    /// Column by index.
    pub fn col(&self, idx: usize) -> &Column {
        &self.cols[idx]
    }

    /// Column by attribute name.
    pub fn col_by_name(&self, name: &str) -> Option<&Column> {
        self.schema.index_of(name).map(|i| &self.cols[i])
    }

    /// Value at (row, col).
    #[inline]
    pub fn value(&self, row: usize, col: usize) -> Value {
        self.cols[col].get(row)
    }

    /// Tuple weight (1.0 unless the relation is grouped).
    #[inline]
    pub fn weight(&self, row: usize) -> f64 {
        match &self.weights {
            Some(w) => w[row],
            None => 1.0,
        }
    }

    /// True if the relation carries explicit tuple weights.
    pub fn has_weights(&self) -> bool {
        self.weights.is_some()
    }

    /// Append a tuple with weight 1.
    pub fn push_row(&mut self, vals: &[Value]) {
        assert_eq!(vals.len(), self.cols.len(), "arity mismatch");
        for (c, v) in self.cols.iter_mut().zip(vals.iter()) {
            c.push(*v);
        }
        if let Some(w) = &mut self.weights {
            w.push(1.0);
        }
        if let Some(idx) = &mut self.row_index {
            idx.entry(encode_row_key(vals)).or_default().push(self.len as u32);
        }
        self.len += 1;
    }

    /// Append a tuple with an explicit weight.
    pub fn push_row_weighted(&mut self, vals: &[Value], weight: f64) {
        if self.weights.is_none() {
            self.weights = Some(vec![1.0; self.len]);
        }
        self.push_row(vals);
        if weight != 1.0 {
            let w = self.weights.as_mut().expect("weights just initialized");
            *w.last_mut().expect("row just pushed") = weight;
        }
    }

    /// Collect one row as values (allocates; use columns directly on hot paths).
    pub fn row(&self, row: usize) -> Vec<Value> {
        (0..self.cols.len()).map(|c| self.value(row, c)).collect()
    }

    /// Ring-style deletion: reduce the multiplicity of the last tuple
    /// matching `vals` by `weight` (a delete is a negative-weight insert;
    /// see [`crate::incremental`]). The tuple's storage is retained with
    /// weight 0 when fully retracted — every consumer (FAQ passes, the
    /// grid coreset, materialization mass) already treats zero-weight
    /// tuples as absent; [`Relation::compact`] reclaims them. Returns
    /// `false` (and changes nothing) when no matching tuple with at least
    /// `weight` multiplicity exists.
    ///
    /// Matching rows are found through a lazily-built value-keyed row
    /// index, so a retraction is O(1) in the relation size (plus the
    /// duplicate count of that one tuple) instead of a newest-first O(n)
    /// scan. The first call after construction or [`Relation::compact`]
    /// pays a one-time O(n) index build.
    ///
    /// Multiplicity arithmetic is exact on the ring ℤ (integer weights —
    /// the streaming contract; see [`crate::incremental`]) and on dyadic
    /// fractions. Arbitrary fractional weights are subject to f64
    /// rounding: repeated partial retraction may leave a tiny residue
    /// instead of reaching the exact 0.0 tombstone, and the aggregate
    /// availability check then rejects the final retraction.
    pub fn retract_row(&mut self, vals: &[Value], weight: f64) -> bool {
        if vals.len() != self.cols.len() || !(weight > 0.0) {
            return false;
        }
        // NaN never compares equal, so the pre-index linear scan could
        // never match such a tuple; preserve that under the bit-keyed
        // index.
        if vals.iter().any(|v| matches!(v, Value::Double(x) if x.is_nan())) {
            return false;
        }
        self.ensure_index();
        let key = encode_row_key(vals);
        let candidates: Vec<u32> = match self.row_index.as_ref().expect("index built").get(&key) {
            None => return false,
            Some(rows) => rows.clone(),
        };
        // The tuple's multiplicity is the *aggregate* over all stored
        // rows with these values (duplicate unit inserts accumulate), so
        // retraction spreads over matching rows, newest first — matching
        // the value-multiset semantics of the incremental delta state.
        // Candidates are value-verified: the index key is a hash encoding.
        let matches: Vec<usize> = candidates
            .iter()
            .rev()
            .map(|&r| r as usize)
            .filter(|&r| {
                self.weight(r) > 0.0
                    && (0..self.cols.len()).all(|c| self.value(r, c) == vals[c])
            })
            .collect();
        let available: f64 = matches.iter().map(|&r| self.weight(r)).sum();
        if available < weight {
            return false;
        }
        if self.weights.is_none() {
            self.weights = Some(vec![1.0; self.len]);
        }
        let w = self.weights.as_mut().expect("weights just initialized");
        let mut remaining = weight;
        let mut zeroed: Vec<u32> = Vec::new();
        for &r in &matches {
            if remaining <= 0.0 {
                break;
            }
            let take = remaining.min(w[r]);
            w[r] -= take;
            remaining -= take;
            if w[r] == 0.0 {
                self.zero_rows += 1;
                zeroed.push(r as u32);
            }
        }
        // Fully-retracted rows leave the index (they can never match
        // again); empty entries are dropped so the index tracks the live
        // tuple set.
        if !zeroed.is_empty() {
            let idx = self.row_index.as_mut().expect("index built");
            if let Some(entry) = idx.get_mut(&key) {
                entry.retain(|r| !zeroed.contains(r));
                if entry.is_empty() {
                    idx.remove(&key);
                }
            }
        }
        true
    }

    /// Build the value-keyed row index over live (positive-weight) rows.
    fn ensure_index(&mut self) {
        if self.row_index.is_some() {
            return;
        }
        let mut idx: FxHashMap<Vec<u64>, Vec<u32>> = FxHashMap::default();
        for r in 0..self.len {
            if self.weight(r) == 0.0 {
                continue;
            }
            idx.entry(encode_row_key(&self.row(r))).or_default().push(r as u32);
        }
        self.row_index = Some(idx);
    }

    /// Number of fully-retracted (zero-weight) tuples still occupying
    /// storage.
    pub fn zero_rows(&self) -> usize {
        self.zero_rows
    }

    /// Drop zero-weight tuples, reclaiming their storage. Returns the
    /// number of tuples removed. The streaming coordinator calls this
    /// when retracted tombstones start to dominate a relation, bounding
    /// both memory and the `retract_row` scan under delete-heavy load.
    pub fn compact(&mut self) -> usize {
        if self.zero_rows == 0 {
            return 0;
        }
        let keep: Vec<usize> =
            (0..self.len).filter(|&r| self.weight(r) != 0.0).collect();
        let removed = self.len - keep.len();
        for col in self.cols.iter_mut() {
            match col {
                Column::Int(v) => {
                    let nv: Vec<i64> = keep.iter().map(|&r| v[r]).collect();
                    *v = nv;
                }
                Column::Double(v) => {
                    let nv: Vec<f64> = keep.iter().map(|&r| v[r]).collect();
                    *v = nv;
                }
                Column::Cat(v) => {
                    let nv: Vec<CatId> = keep.iter().map(|&r| v[r]).collect();
                    *v = nv;
                }
            }
        }
        if let Some(w) = &mut self.weights {
            let nw: Vec<f64> = keep.iter().map(|&r| w[r]).collect();
            *w = nw;
        }
        self.len = keep.len();
        self.zero_rows = 0;
        // Row ids shifted: drop the index; the next retraction rebuilds
        // it over the compacted storage (coherent by construction).
        self.row_index = None;
        removed
    }

    /// Estimated in-memory size in bytes (for Table-1 style reporting),
    /// including the value-keyed row index once a retraction has built it.
    pub fn byte_size(&self) -> u64 {
        let per_row: u64 = self
            .schema
            .attrs()
            .iter()
            .map(|a| match a.ty {
                AttrType::Int => 8,
                AttrType::Double => 8,
                AttrType::Cat => 4,
            })
            .sum();
        let weight_bytes = if self.weights.is_some() { 8 * self.len as u64 } else { 0 };
        let mut total = per_row * self.len as u64 + weight_bytes;
        if let Some(idx) = &self.row_index {
            // Per entry: encoded key (one u64 per column + Vec header) and
            // the row-id list (u32 per live duplicate + Vec header).
            let key_bytes = 24 + 8 * self.cols.len() as u64;
            total += idx.len() as u64 * key_bytes;
            // rklint::allow(nondet-iteration, reason = "u64 size estimate: integer addition is exact and commutative, so order cannot change the total")
            total += idx.values().map(|v| 24 + 4 * v.len() as u64).sum::<u64>();
        }
        total
    }

    /// Distinct values (by join key) in a column. Panics for Double columns.
    pub fn distinct_keys(&self, col: usize) -> Vec<u64> {
        let mut keys: Vec<u64> = (0..self.len).map(|r| self.cols[col].key_u64(r)).collect();
        keys.sort_unstable();
        keys.dedup();
        keys
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::schema::Attr;

    fn sample() -> Relation {
        let mut r = Relation::new(
            "t",
            Schema::new(vec![Attr::int("id"), Attr::double("x"), Attr::cat("c", 4)]),
        );
        r.push_row(&[Value::Int(1), Value::Double(0.5), Value::Cat(2)]);
        r.push_row(&[Value::Int(2), Value::Double(1.5), Value::Cat(2)]);
        r
    }

    #[test]
    fn push_and_read_back() {
        let r = sample();
        assert_eq!(r.n_rows(), 2);
        assert_eq!(r.value(0, 0), Value::Int(1));
        assert_eq!(r.value(1, 1), Value::Double(1.5));
        assert_eq!(r.value(1, 2), Value::Cat(2));
        assert_eq!(r.weight(0), 1.0);
        assert!(!r.has_weights());
    }

    #[test]
    fn weighted_rows_backfill_ones() {
        let mut r = sample();
        r.push_row_weighted(&[Value::Int(3), Value::Double(2.0), Value::Cat(0)], 4.5);
        assert!(r.has_weights());
        assert_eq!(r.weight(0), 1.0);
        assert_eq!(r.weight(2), 4.5);
    }

    #[test]
    fn distinct_keys_dedup() {
        let r = sample();
        assert_eq!(r.distinct_keys(2), vec![2]);
        assert_eq!(r.distinct_keys(0), vec![1, 2]);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_mismatch_panics() {
        let mut r = sample();
        r.push_row(&[Value::Int(1)]);
    }

    #[test]
    fn retract_reduces_multiplicity() {
        let mut r = sample();
        // Full retraction leaves a zero-weight tuple behind.
        assert!(r.retract_row(&[Value::Int(1), Value::Double(0.5), Value::Cat(2)], 1.0));
        assert_eq!(r.weight(0), 0.0);
        assert_eq!(r.weight(1), 1.0);
        // Nothing left to retract for that tuple.
        assert!(!r.retract_row(&[Value::Int(1), Value::Double(0.5), Value::Cat(2)], 1.0));
        // Unknown tuple and arity mismatch are no-ops.
        assert!(!r.retract_row(&[Value::Int(9), Value::Double(0.5), Value::Cat(2)], 1.0));
        assert!(!r.retract_row(&[Value::Int(2)], 1.0));
        // Partial retraction of a weighted tuple.
        r.push_row_weighted(&[Value::Int(3), Value::Double(2.0), Value::Cat(0)], 3.0);
        assert!(r.retract_row(&[Value::Int(3), Value::Double(2.0), Value::Cat(0)], 2.0));
        assert_eq!(r.weight(2), 1.0);
    }

    #[test]
    fn retraction_spans_duplicate_rows() {
        // Aggregate multiplicity from duplicate unit inserts is
        // retractable in one weighted call (value-multiset semantics).
        let mut r = Relation::new("t", Schema::new(vec![Attr::cat("c", 4)]));
        r.push_row(&[Value::Cat(1)]);
        r.push_row(&[Value::Cat(1)]);
        r.push_row(&[Value::Cat(2)]);
        assert!(r.retract_row(&[Value::Cat(1)], 2.0));
        assert_eq!(r.weight(0), 0.0);
        assert_eq!(r.weight(1), 0.0);
        assert_eq!(r.zero_rows(), 2);
        // Over-retraction of the remaining tuple is refused whole.
        assert!(!r.retract_row(&[Value::Cat(2)], 2.0));
        assert_eq!(r.weight(2), 1.0);
    }

    #[test]
    fn compact_reclaims_zero_rows() {
        let mut r = sample();
        r.push_row(&[Value::Int(3), Value::Double(2.5), Value::Cat(1)]);
        assert!(r.retract_row(&[Value::Int(2), Value::Double(1.5), Value::Cat(2)], 1.0));
        assert_eq!(r.zero_rows(), 1);
        assert_eq!(r.compact(), 1);
        assert_eq!(r.n_rows(), 2);
        assert_eq!(r.zero_rows(), 0);
        // Survivors keep their values and weights in order.
        assert_eq!(r.value(0, 0), Value::Int(1));
        assert_eq!(r.value(1, 0), Value::Int(3));
        assert_eq!(r.weight(0), 1.0);
        // Idempotent when nothing is retracted.
        assert_eq!(r.compact(), 0);
    }

    #[test]
    fn indexed_retraction_handles_interleaved_ops() {
        let mut r = Relation::new("t", Schema::new(vec![Attr::cat("c", 8), Attr::double("x")]));
        for i in 0..10u32 {
            r.push_row(&[Value::Cat(i % 2), Value::Double((i % 3) as f64)]);
        }
        // (0, 0.0) occurs at i ∈ {0, 6}: aggregate multiplicity 2.
        assert!(r.retract_row(&[Value::Cat(0), Value::Double(0.0)], 2.0));
        assert!(!r.retract_row(&[Value::Cat(0), Value::Double(0.0)], 1.0));
        // Rows pushed after the index exists are retractable too.
        r.push_row(&[Value::Cat(0), Value::Double(0.0)]);
        assert!(r.retract_row(&[Value::Cat(0), Value::Double(0.0)], 1.0));
        assert_eq!(r.zero_rows(), 3);
        // Compaction shifts row ids; the index stays coherent (rebuilt).
        assert_eq!(r.compact(), 3);
        assert_eq!(r.n_rows(), 8);
        assert!(r.retract_row(&[Value::Cat(1), Value::Double(1.0)], 1.0));
        assert!(!r.retract_row(&[Value::Cat(7), Value::Double(9.9)], 1.0));
    }

    #[test]
    fn index_verifies_values_not_just_keys() {
        // Int(5) and Cat(5) share a key encoding but must not cross-match.
        let mut r = Relation::new("t", Schema::new(vec![Attr::int("i")]));
        r.push_row(&[Value::Int(5)]);
        assert!(!r.retract_row(&[Value::Cat(5)], 1.0));
        assert!(r.retract_row(&[Value::Int(5)], 1.0));
    }

    #[test]
    fn nan_tuples_never_match() {
        let mut r = Relation::new("t", Schema::new(vec![Attr::double("x")]));
        r.push_row(&[Value::Double(f64::NAN)]);
        assert!(!r.retract_row(&[Value::Double(f64::NAN)], 1.0));
    }

    #[test]
    fn negative_zero_matches_positive_zero() {
        let mut r = Relation::new("t", Schema::new(vec![Attr::double("x")]));
        r.push_row(&[Value::Double(0.0)]);
        assert!(r.retract_row(&[Value::Double(-0.0)], 1.0));
        r.push_row(&[Value::Double(-0.0)]);
        assert!(r.retract_row(&[Value::Double(0.0)], 1.0));
    }

    #[test]
    fn byte_size_counts_weights() {
        let mut r = sample();
        let base = r.byte_size();
        r.push_row_weighted(&[Value::Int(3), Value::Double(2.0), Value::Cat(0)], 2.0);
        assert!(r.byte_size() > base);
    }

    #[test]
    fn byte_size_counts_the_row_index() {
        let mut r = sample();
        let before = r.byte_size();
        // The first retraction builds the index; reported memory grows.
        assert!(r.retract_row(&[Value::Int(1), Value::Double(0.5), Value::Cat(2)], 1.0));
        assert!(r.byte_size() > before);
    }
}
