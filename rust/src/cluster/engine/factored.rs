//! Factored (grid-coreset) weighted Lloyd through the shared engine
//! (paper §4.3, Eqs. 36–38).
//!
//! Distances stay in factored form: a per-iteration `O(Σκ_j·k)` table
//! build turns each (cell, centroid) distance into `m` table lookups, and
//! the Hamerly bounds live **per grid cell**. Under Elkan bounds a cell
//! that fails the global test still prunes within its scan: each
//! centroid whose per-centroid row bound clears the exact assigned
//! distance is skipped inside the m-lookup loop (provably outside the
//! argmin, so the result stays bitwise identical; skips are visible in
//! [`PruneStats::bound_evals`](super::PruneStats::bound_evals) /
//! `dist_evals_skipped`). Centroid drift and the
//! inter-centroid separations `s[c]` are computed straight from the β
//! coefficient tables using component orthogonality
//! (`‖μ − μ'‖² = Σ_j λ_j Σ_a (β_a − β'_a)²·‖u_a‖²`), so the pruning
//! machinery never densifies a centroid either. The bounds test, ordered
//! accumulation, reseed picker and convergence test are the shared
//! [`core`](super::core) helpers; see the parent module docs for the
//! bounds invariants and the determinism contract.
//!
//! [`lloyd_factored_init`] accepts a warm start: the incremental planner
//! re-clusters a patched grid from the previous version's centroids, which
//! typically converges in one or two iterations instead of a full run.

use super::core::{
    accumulate_pass, bounds_filter, converged, fold_chunk_stats, half_min_separation,
    record_scan, reseed_target, BoundsCtx, ChunkState, ChunkStats,
};
use super::microkernel::{best_two_buf, best_two_buf_f32};
use super::{
    resolve_threads, BoundsPolicy, EngineOpts, EngineState, Precision, PruneStats, CHUNK,
    SLACK_REL, SLACK_REL_F32,
};
use crate::cluster::kmeanspp::kmeanspp_indices;
use crate::cluster::lloyd::LloydConfig;
use crate::cluster::sparse_lloyd::{
    cell_dist2, CentroidCoord, Components, SparseGrid, SparseLloydResult, Subspace,
};
use crate::util::SplitMix64;

/// Squared distance between two factored centroids (also the squared
/// drift when `a` is a centroid's previous position): orthogonality makes
/// every subspace term a coefficient-space quadratic. Shared with the
/// ladder-sweep seeding in `crate::rkmeans::pipeline`.
pub(crate) fn factored_dist2(
    a: &[CentroidCoord],
    b: &[CentroidCoord],
    subspaces: &[Subspace],
) -> f64 {
    let mut acc = 0.0;
    for ((ca, cb), sub) in a.iter().zip(b).zip(subspaces) {
        let dj = match (ca, cb, &sub.comp) {
            (CentroidCoord::Continuous(x), CentroidCoord::Continuous(y), _) => {
                let t = x - y;
                t * t
            }
            (
                CentroidCoord::Categorical(bx),
                CentroidCoord::Categorical(by),
                Components::Categorical { norm_sq },
            ) => bx
                .iter()
                .zip(by)
                .zip(norm_sq)
                .map(|((x, y), nq)| (x - y) * (x - y) * nq)
                .sum(),
            _ => unreachable!("subspace kind is fixed"),
        };
        acc += sub.lambda * dj;
    }
    acc
}

/// Indicator-coefficient centroid at a grid cell (used for seeding,
/// empty-cluster reseeds, and the ladder-sweep D² fill in
/// `crate::rkmeans::pipeline`).
pub(crate) fn centroid_from_cell(
    grid: &SparseGrid,
    subspaces: &[Subspace],
    cell: usize,
) -> Vec<CentroidCoord> {
    let row = grid.row(cell);
    subspaces
        .iter()
        .enumerate()
        .map(|(j, sub)| match &sub.comp {
            Components::Continuous { centers } => {
                CentroidCoord::Continuous(centers[row[j] as usize])
            }
            Components::Categorical { norm_sq } => {
                let mut beta = vec![0.0; norm_sq.len()];
                beta[row[j] as usize] = 1.0;
                CentroidCoord::Categorical(beta)
            }
        })
        .collect()
}

/// True when a warm-start candidate matches the problem's factored shape
/// (k centroids × m subspaces, β lengths equal to each κ_j).
fn warm_start_valid(init: &[Vec<CentroidCoord>], k: usize, subspaces: &[Subspace]) -> bool {
    if init.len() != k {
        return false;
    }
    init.iter().all(|cent| {
        cent.len() == subspaces.len()
            && cent.iter().zip(subspaces).all(|(coord, sub)| match (coord, &sub.comp) {
                (CentroidCoord::Continuous(_), Components::Continuous { .. }) => true,
                (CentroidCoord::Categorical(beta), Components::Categorical { norm_sq }) => {
                    beta.len() == norm_sq.len()
                }
                _ => false,
            })
    })
}

/// Build the per-subspace distance tables `T_j[a·k + c]` for the current
/// centroids (identical arithmetic to the pre-engine implementation).
fn build_tables(
    subspaces: &[Subspace],
    kappa: &[usize],
    centroids: &[Vec<CentroidCoord>],
    k: usize,
) -> Vec<Vec<f64>> {
    subspaces
        .iter()
        .enumerate()
        .map(|(j, sub)| {
            let kj = kappa[j];
            let mut t = vec![0.0f64; kj * k];
            match &sub.comp {
                Components::Continuous { centers } => {
                    for (c, cent) in centroids.iter().enumerate() {
                        let CentroidCoord::Continuous(mu) = &cent[j] else {
                            unreachable!("subspace kind is fixed")
                        };
                        for a in 0..kj {
                            let dd = centers[a] - mu;
                            t[a * k + c] = sub.lambda * dd * dd;
                        }
                    }
                }
                Components::Categorical { norm_sq } => {
                    for (c, cent) in centroids.iter().enumerate() {
                        let CentroidCoord::Categorical(beta) = &cent[j] else {
                            unreachable!("subspace kind is fixed")
                        };
                        // S = Σ_b β²·‖u_b‖² (centroid's squared norm).
                        let s_c: f64 = beta.iter().zip(norm_sq).map(|(b, nq)| b * b * nq).sum();
                        for a in 0..kj {
                            let dd = norm_sq[a] - 2.0 * beta[a] * norm_sq[a] + s_c;
                            t[a * k + c] = sub.lambda * dd.max(0.0);
                        }
                    }
                }
            }
            t
        })
        .collect()
}

/// One chunk's view of the per-cell state plus its accumulators.
struct FacChunk<'a> {
    /// `len × m` component ids for this chunk's cells.
    gids: &'a [u32],
    st: ChunkState<'a>,
    mass: Vec<f64>,
    /// `comp_mass[j][c·κ_j + a]` = weight of cells in `c` with `g_j = a`.
    comp_mass: Vec<Vec<f64>>,
    obj: f64,
    stats: ChunkStats,
}

/// Read-only per-iteration context. Exactly one of `tables` / `tables32`
/// is populated, matching `precision`.
struct FacCtx<'a> {
    m: usize,
    k: usize,
    kappa: &'a [usize],
    tables: &'a [Vec<f64>],
    tables32: &'a [Vec<f32>],
    precision: Precision,
    bounds: BoundsPolicy,
    drift: &'a [f64],
    drift_max: f64,
    s_half: &'a [f64],
    slack: f64,
    use_bounds: bool,
    pruning: bool,
}

/// Exact distance of one cell to one centroid: `m` table lookups, summed
/// in subspace order (bitwise-identical to the full-scan accumulation).
#[inline]
fn cell_centroid_dd(gids: &[u32], tables: &[Vec<f64>], k: usize, c: usize) -> f64 {
    let mut dd = tables[0][gids[0] as usize * k + c];
    for (j, tj) in tables.iter().enumerate().skip(1) {
        dd += tj[gids[j] as usize * k + c];
    }
    dd
}

/// f32 twin of [`cell_centroid_dd`] (same subspace-order accumulation,
/// bitwise-identical to the f32 full scan).
#[inline]
fn cell_centroid_dd_f32(gids: &[u32], tables: &[Vec<f32>], k: usize, c: usize) -> f32 {
    let mut dd = tables[0][gids[0] as usize * k + c];
    for (j, tj) in tables.iter().enumerate().skip(1) {
        dd += tj[gids[j] as usize * k + c];
    }
    dd
}

fn assign_chunk(ch: &mut FacChunk, ctx: &FacCtx) {
    let (m, k) = (ctx.m, ctx.k);
    let gids = ch.gids;

    // Table sums are non-negative by construction, so no clamping is
    // applied in either phase or precision (matching the full scan).
    let bctx = BoundsCtx {
        k,
        bounds: ctx.bounds,
        drift_max: ctx.drift_max,
        drift: ctx.drift,
        s_half: ctx.s_half,
        slack: ctx.slack,
        use_bounds: ctx.use_bounds,
        pruning: ctx.pruning,
    };

    match ctx.precision {
        Precision::F64 => {
            // Phase 1: bounds test (shared).
            let scan = bounds_filter(&mut ch.st, &bctx, &mut ch.stats, |i, a| {
                cell_centroid_dd(&gids[i * m..(i + 1) * m], ctx.tables, k, a)
            });

            if bctx.use_bounds && bctx.bounds == BoundsPolicy::Elkan {
                // Phase 2, Elkan: within-scan per-centroid pruning. A
                // point that failed the global test can still skip any
                // centroid whose (drifted) row bound clears the exact
                // assigned distance — `lb[i·k + c] > ub + slack` proves
                // `dd_c > dd_a ≥ d1` under the same slack argument as the
                // Phase-1 skip, so the evaluated argmin (first strict
                // minimum, as in `best_two_buf`) is unchanged bitwise.
                // Evaluated centroids refresh their bound to the exact
                // distance (as a full row refresh would); skipped ones
                // keep the drifted — still valid — bound. The partial d2
                // only overestimates the second-best distance, which
                // feeds nothing but the `max_dd` slack scale.
                for &gi in &scan {
                    let i = gi as usize;
                    let row = &gids[i * m..(i + 1) * m];
                    let a = ch.st.assign[i] as usize;
                    let lb_row = &mut ch.st.lb[i * k..(i + 1) * k];
                    let ub = lb_row[a];
                    let (mut d1, mut c1, mut d2) = (f64::INFINITY, 0u32, f64::INFINITY);
                    let mut evaluated = 0u64;
                    for (c, b) in lb_row.iter_mut().enumerate() {
                        if c != a && *b > ub + ctx.slack {
                            continue;
                        }
                        let dd = cell_centroid_dd(row, ctx.tables, k, c);
                        *b = dd.max(0.0).sqrt();
                        evaluated += 1;
                        if dd < d1 {
                            d2 = d1;
                            d1 = dd;
                            c1 = c as u32;
                        } else if dd < d2 {
                            d2 = dd;
                        }
                    }
                    ch.st.assign[i] = c1;
                    ch.st.mind2[i] = d1;
                    ch.stats.evals += evaluated;
                    ch.stats.skipped += k as u64 - evaluated;
                    ch.stats.bound_evals += k as u64 - 1;
                    if d1 > ch.stats.max_dd {
                        ch.stats.max_dd = d1;
                    }
                    if d2.is_finite() && d2 > ch.stats.max_dd {
                        ch.stats.max_dd = d2;
                    }
                }
            } else {
                // Phase 2: full scans — the factored m-lookup
                // accumulation over all centroids.
                let mut dist_buf = vec![0.0f64; k];
                for &gi in &scan {
                    let i = gi as usize;
                    let row = &gids[i * m..(i + 1) * m];
                    let base0 = row[0] as usize * k;
                    dist_buf.copy_from_slice(&ctx.tables[0][base0..base0 + k]);
                    for j in 1..m {
                        let base = row[j] as usize * k;
                        let tj = &ctx.tables[j][base..base + k];
                        for (dv, &t) in dist_buf.iter_mut().zip(tj) {
                            *dv += t;
                        }
                    }
                    let (d1, c1, d2) = best_two_buf(&dist_buf);
                    let buf = &dist_buf;
                    record_scan(&mut ch.st, &mut ch.stats, i, c1, d1, d2, &bctx, |c| buf[c]);
                }
            }
        }
        Precision::F32 => {
            // Phase 1 through the f32 tables — bitwise consistent with
            // the f32 scan below.
            let scan = bounds_filter(&mut ch.st, &bctx, &mut ch.stats, |i, a| {
                cell_centroid_dd_f32(&gids[i * m..(i + 1) * m], ctx.tables32, k, a) as f64
            });

            if bctx.use_bounds && bctx.bounds == BoundsPolicy::Elkan {
                // Phase 2, Elkan: within-scan per-centroid pruning (see
                // the f64 arm). Kernel sums and the best-two comparison
                // stay in f32 — bitwise identical to `best_two_buf_f32`
                // over the evaluated set — while the bound test and the
                // refreshed bounds use the same f64 arithmetic as the
                // full-row refresh.
                for &gi in &scan {
                    let i = gi as usize;
                    let row = &gids[i * m..(i + 1) * m];
                    let a = ch.st.assign[i] as usize;
                    let lb_row = &mut ch.st.lb[i * k..(i + 1) * k];
                    let ub = lb_row[a];
                    let (mut d1, mut c1, mut d2) = (f32::INFINITY, 0u32, f32::INFINITY);
                    let mut evaluated = 0u64;
                    for (c, b) in lb_row.iter_mut().enumerate() {
                        if c != a && *b > ub + ctx.slack {
                            continue;
                        }
                        let dd = cell_centroid_dd_f32(row, ctx.tables32, k, c);
                        *b = (dd as f64).max(0.0).sqrt();
                        evaluated += 1;
                        if dd < d1 {
                            d2 = d1;
                            d1 = dd;
                            c1 = c as u32;
                        } else if dd < d2 {
                            d2 = dd;
                        }
                    }
                    ch.st.assign[i] = c1;
                    ch.st.mind2[i] = d1 as f64;
                    ch.stats.evals += evaluated;
                    ch.stats.skipped += k as u64 - evaluated;
                    ch.stats.bound_evals += k as u64 - 1;
                    if d1 as f64 > ch.stats.max_dd {
                        ch.stats.max_dd = d1 as f64;
                    }
                    if d2.is_finite() && d2 as f64 > ch.stats.max_dd {
                        ch.stats.max_dd = d2 as f64;
                    }
                }
            } else {
                // Phase 2: the same m-lookup accumulation in f32 (2×
                // lanes on the per-cell table sums).
                let mut dist_buf = vec![0.0f32; k];
                for &gi in &scan {
                    let i = gi as usize;
                    let row = &gids[i * m..(i + 1) * m];
                    let base0 = row[0] as usize * k;
                    dist_buf.copy_from_slice(&ctx.tables32[0][base0..base0 + k]);
                    for j in 1..m {
                        let base = row[j] as usize * k;
                        let tj = &ctx.tables32[j][base..base + k];
                        for (dv, &t) in dist_buf.iter_mut().zip(tj) {
                            *dv += t;
                        }
                    }
                    let (d1, c1, d2) = best_two_buf_f32(&dist_buf);
                    let buf = &dist_buf;
                    record_scan(
                        &mut ch.st,
                        &mut ch.stats,
                        i,
                        c1,
                        d1 as f64,
                        d2 as f64,
                        &bctx,
                        |c| buf[c] as f64,
                    );
                }
            }
        }
    }

    // Phase 3: ordered objective + mass accumulation (shared; f64 in
    // both precisions — the f32 tolerance contract).
    let comp_mass = &mut ch.comp_mass;
    let kappa = ctx.kappa;
    accumulate_pass(ch.st.w, ch.st.assign, ch.st.mind2, &mut ch.obj, &mut ch.mass, |i, c, w| {
        let row = &gids[i * m..(i + 1) * m];
        for j in 0..m {
            comp_mass[j][c * kappa[j] + row[j] as usize] += w;
        }
    });
}

/// Factored weighted Lloyd over the grid coreset with engine options.
pub fn lloyd_factored(
    grid: &SparseGrid,
    subspaces: &[Subspace],
    cfg: &LloydConfig,
    opts: &EngineOpts,
) -> (SparseLloydResult, PruneStats) {
    lloyd_factored_init(grid, subspaces, cfg, opts, None)
}

/// [`lloyd_factored`] with an optional warm start: when `init` holds a
/// shape-valid set of `k` factored centroids they seed the run in place of
/// k-means++. A shape mismatch (wrong k after a grid shrink, stale κ_j
/// after a Step-2 re-solve) silently falls back to fresh seeding, so the
/// incremental planner can always pass its previous centroids.
/// `init = None` is bitwise-identical to [`lloyd_factored`].
pub fn lloyd_factored_init(
    grid: &SparseGrid,
    subspaces: &[Subspace],
    cfg: &LloydConfig,
    opts: &EngineOpts,
    init: Option<&[Vec<CentroidCoord>]>,
) -> (SparseLloydResult, PruneStats) {
    let (res, stats, _) = lloyd_factored_resume(grid, subspaces, cfg, opts, init, None);
    (res, stats)
}

/// [`lloyd_factored_init`] with cross-run state carry: always returns the
/// run's carryable [`EngineState`], and accepts the previous run's state
/// so iteration 0 reuses its assignments and bounds instead of a full
/// first scan — the incremental planner's patch path splices the state
/// across grid edits ([`EngineState::splice`]) and resumes here, making
/// per-batch Step-4 cost `O(b + changed cells)`. A resumed run is
/// **bitwise identical** to the same warm start without `resume`.
///
/// Panics when `resume` is stale — captured against different centroids
/// than this run starts from (including the case where a shape-invalid
/// `init` silently fell back to fresh seeding), or a different cell
/// count. A bounds-policy or precision mismatch merely degrades to the
/// cold warm start.
pub fn lloyd_factored_resume(
    grid: &SparseGrid,
    subspaces: &[Subspace],
    cfg: &LloydConfig,
    opts: &EngineOpts,
    init: Option<&[Vec<CentroidCoord>]>,
    resume: Option<&EngineState>,
) -> (SparseLloydResult, PruneStats, EngineState) {
    let n = grid.n();
    assert!(n > 0, "empty grid");
    assert_eq!(grid.m, subspaces.len());
    assert!(grid.m > 0, "need at least one subspace");
    // k-means++ always yields at least one seed, so treat k = 0 as 1.
    let k = cfg.k.min(n).max(1);
    let m = grid.m;
    let t0 = crate::util::timer::now();

    let mut centroids: Vec<Vec<CentroidCoord>> = match init {
        Some(c0) if warm_start_valid(c0, k, subspaces) => c0.to_vec(),
        _ => {
            let mut rng = SplitMix64::new(cfg.seed);
            let seeds = kmeanspp_indices(n, &grid.weights, k, &mut rng, |i, j| {
                cell_dist2(grid, subspaces, i, j)
            });
            seeds.iter().map(|&s| centroid_from_cell(grid, subspaces, s)).collect()
        }
    };

    let kappa: Vec<usize> = subspaces.iter().map(|s| s.comp.len()).collect();

    // Scale term for the FP slack: the largest possible cell norm²
    // Σ_j λ_j·max_a ‖u_a‖² — the factored analog of the dense engine's
    // `xn_max`. Absolute rounding in the categorical distance expansion
    // (`‖u_a‖² − 2β_a‖u_a‖² + S`) is proportional to these magnitudes,
    // not to the distances themselves, so the skip slack must cover it.
    let norm2_max: f64 = subspaces
        .iter()
        .map(|sub| {
            let comp_max = match &sub.comp {
                Components::Continuous { centers } => {
                    centers.iter().map(|c| c * c).fold(0.0f64, f64::max)
                }
                Components::Categorical { norm_sq } => {
                    norm_sq.iter().cloned().fold(0.0f64, f64::max)
                }
            };
            sub.lambda * comp_max
        })
        .sum();

    let bounds = opts.bounds.resolve(k);
    // Per-(cell, centroid) lower-bound rows for Elkan, one global bound
    // per cell otherwise.
    let lb_stride = if opts.pruning && bounds == BoundsPolicy::Elkan { k } else { 1 };
    let f32_kernel = opts.precision == Precision::F32;
    let slack_rel = match opts.precision {
        Precision::F64 => SLACK_REL,
        Precision::F32 => SLACK_REL_F32,
    };

    let threads = resolve_threads(opts.threads);
    let mut assign = vec![0u32; n];
    let mut mind2 = vec![0.0f64; n];
    let mut lb = vec![0.0f64; n * lb_stride];
    let mut drift = vec![0.0f64; k];
    let mut s_half = vec![0.0f64; k];
    let mut bounds_valid = false;
    let mut max_dd = 0.0f64;

    // Cross-run state carry (see the parent module docs): a valid prior
    // state seeds assignments and final-centroid-drifted bounds, so
    // iteration 0 runs with `use_bounds = true` and zero drift.
    if let Some(st) = resume {
        let start_hash = EngineState::hash_factored(&centroids);
        bounds_valid =
            st.resume_into(start_hash, k, opts, bounds, &mut assign, &mut lb, "cells");
    }

    let mut objective = f64::INFINITY;
    let mut iters = 0;
    let mut stats = PruneStats {
        points: n as u64,
        bounds: if opts.pruning { bounds.label() } else { "none" },
        precision: opts.precision.label(),
        executor: opts.executor.label(),
        ..PruneStats::default()
    };

    for it in 0..cfg.max_iters.max(1) {
        iters = it + 1;

        // The per-iteration tables are built in f64 either way (an
        // O(Σκ_j·k) cold path); the f32 kernel reads a narrowed copy so
        // the O(|G|·k·m) sum loop runs at twice the lane width.
        let tables = build_tables(subspaces, &kappa, &centroids, k);
        let tables32: Vec<Vec<f32>> = if f32_kernel {
            tables.iter().map(|t| t.iter().map(|&v| v as f32).collect()).collect()
        } else {
            Vec::new()
        };
        let use_bounds = opts.pruning && bounds_valid;
        if use_bounds {
            half_min_separation(k, &mut s_half, |c, c2| {
                factored_dist2(&centroids[c], &centroids[c2], subspaces)
            });
        }
        let drift_max = drift.iter().cloned().fold(0.0f64, f64::max);
        let slack = slack_rel * (1.0 + 2.0 * max_dd.sqrt() + norm2_max.sqrt());
        let ctx = FacCtx {
            m,
            k,
            kappa: &kappa,
            tables: &tables,
            tables32: &tables32,
            precision: opts.precision,
            bounds,
            drift: &drift,
            drift_max,
            s_half: &s_half,
            slack,
            use_bounds,
            pruning: opts.pruning,
        };

        #[allow(clippy::type_complexity)]
        let chunks_out: Vec<(Vec<f64>, Vec<Vec<f64>>, f64, ChunkStats)> = {
            let mut chunks: Vec<FacChunk> = Vec::with_capacity(n.div_ceil(CHUNK));
            let parts = assign
                .chunks_mut(CHUNK)
                .zip(mind2.chunks_mut(CHUNK))
                .zip(lb.chunks_mut(CHUNK * lb_stride));
            let mut start = 0usize;
            for ((a_s, m_s), l_s) in parts {
                let len = a_s.len();
                chunks.push(FacChunk {
                    gids: &grid.gids[start * m..(start + len) * m],
                    st: ChunkState {
                        w: &grid.weights[start..start + len],
                        assign: a_s,
                        mind2: m_s,
                        lb: l_s,
                    },
                    mass: vec![0.0; k],
                    comp_mass: kappa.iter().map(|&kj| vec![0.0; k * kj]).collect(),
                    obj: 0.0,
                    stats: ChunkStats::default(),
                });
                start += len;
            }
            if opts.executor.run_chunks(&mut chunks, threads, |_, ch| assign_chunk(ch, &ctx)) {
                stats.pool_dispatches += 1;
            }
            chunks.into_iter().map(|c| (c.mass, c.comp_mass, c.obj, c.stats)).collect()
        };

        // Fixed-order reduction.
        let mut mass = vec![0.0f64; k];
        let mut comp_mass: Vec<Vec<f64>> = kappa.iter().map(|&kj| vec![0.0; k * kj]).collect();
        let mut obj = 0.0f64;
        for (c_mass, c_comp, c_obj, c_stats) in &chunks_out {
            for (mv, &v) in mass.iter_mut().zip(c_mass) {
                *mv += v;
            }
            for (cm, acm) in comp_mass.iter_mut().zip(c_comp) {
                for (cv, &v) in cm.iter_mut().zip(acm) {
                    *cv += v;
                }
            }
            obj += c_obj;
            fold_chunk_stats(&mut stats, &mut max_dd, c_stats);
        }

        // Update (identical to the pre-engine implementation) + drift.
        let prev = if opts.pruning { Some(centroids.clone()) } else { None };
        let mut reseeded = false;
        for c in 0..k {
            if mass[c] > 0.0 {
                for (j, sub) in subspaces.iter().enumerate() {
                    let kj = kappa[j];
                    let cm = &comp_mass[j][c * kj..(c + 1) * kj];
                    match (&sub.comp, &mut centroids[c][j]) {
                        (Components::Continuous { centers }, CentroidCoord::Continuous(mu)) => {
                            let s: f64 = cm.iter().zip(centers).map(|(w, v)| w * v).sum();
                            *mu = s / mass[c];
                        }
                        (Components::Categorical { .. }, CentroidCoord::Categorical(beta)) => {
                            for a in 0..kj {
                                beta[a] = cm[a] / mass[c];
                            }
                        }
                        _ => unreachable!("subspace kind is fixed"),
                    }
                }
            } else {
                // Empty cluster: reseed at the heaviest-cost cell.
                let far = reseed_target(&grid.weights, &mind2);
                centroids[c] = centroid_from_cell(grid, subspaces, far);
                mind2[far] = 0.0;
                reseeded = true;
            }
        }
        if let Some(prev) = prev {
            for c in 0..k {
                drift[c] = factored_dist2(&prev[c], &centroids[c], subspaces).max(0.0).sqrt();
            }
        }
        bounds_valid = opts.pruning && !reseeded;

        if converged(objective, obj, cfg.tol) {
            objective = obj;
            break;
        }
        objective = obj;
    }

    stats.iters = iters;
    stats.wall = t0.elapsed();

    // Capture the carryable end-of-run state (shared helper pre-drifts
    // the bounds to the final centroids).
    let state = EngineState::capture(
        assign.clone(),
        lb,
        bounds,
        opts.precision,
        opts.pruning && bounds_valid,
        &drift,
        k,
        EngineState::hash_factored(&centroids),
    );
    (SparseLloydResult { centroids, assign, objective, iters }, stats, state)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::for_cases;

    fn random_problem(rng: &mut SplitMix64, n: usize) -> (SparseGrid, Vec<Subspace>) {
        let k1 = 2 + rng.below(5) as usize;
        let k2 = 2 + rng.below(5) as usize;
        let subs = vec![
            Subspace {
                name: "x".into(),
                lambda: rng.uniform(0.5, 2.0),
                comp: Components::Continuous {
                    centers: (0..k1).map(|_| rng.uniform(-5.0, 5.0)).collect(),
                },
            },
            Subspace {
                name: "c".into(),
                lambda: rng.uniform(0.5, 2.0),
                comp: Components::Categorical {
                    norm_sq: (0..k2).map(|_| rng.uniform(0.3, 1.0)).collect(),
                },
            },
        ];
        let mut gids = Vec::with_capacity(n * 2);
        let mut weights = Vec::with_capacity(n);
        for _ in 0..n {
            gids.push(rng.below(k1 as u64) as u32);
            gids.push(rng.below(k2 as u64) as u32);
            weights.push(rng.uniform(0.1, 3.0));
        }
        (SparseGrid { m: 2, gids, weights }, subs)
    }

    #[test]
    fn pruned_parallel_matches_naive_bitwise() {
        for_cases(10, |rng| {
            let n = 20 + rng.below(300) as usize;
            let (grid, subs) = random_problem(rng, n);
            let iters = 1 + rng.below(7) as usize;
            let k = 1 + rng.below(6) as usize;
            let cfg = LloydConfig { k, max_iters: iters, tol: 0.0, seed: rng.next_u64() };
            let (a, _) = lloyd_factored(&grid, &subs, &cfg, &EngineOpts::naive_serial());
            let (b, _) = lloyd_factored(&grid, &subs, &cfg, &EngineOpts::pruned().with_threads(3));
            assert_eq!(a.assign, b.assign);
            assert_eq!(a.objective.to_bits(), b.objective.to_bits());
            assert_eq!(a.iters, b.iters);
            for (ca, cb) in a.centroids.iter().zip(&b.centroids) {
                for (xa, xb) in ca.iter().zip(cb) {
                    match (xa, xb) {
                        (CentroidCoord::Continuous(u), CentroidCoord::Continuous(v)) => {
                            assert_eq!(u.to_bits(), v.to_bits())
                        }
                        (CentroidCoord::Categorical(u), CentroidCoord::Categorical(v)) => {
                            assert_eq!(u, v)
                        }
                        _ => panic!("centroid kind mismatch"),
                    }
                }
            }
        });
    }

    #[test]
    fn elkan_pruned_parallel_matches_naive_bitwise() {
        for_cases(10, |rng| {
            let n = 20 + rng.below(300) as usize;
            let (grid, subs) = random_problem(rng, n);
            let iters = 1 + rng.below(7) as usize;
            let k = 1 + rng.below(6) as usize;
            let cfg = LloydConfig { k, max_iters: iters, tol: 0.0, seed: rng.next_u64() };
            let (a, _) = lloyd_factored(&grid, &subs, &cfg, &EngineOpts::naive_serial());
            let opts = EngineOpts::pruned().with_bounds(BoundsPolicy::Elkan).with_threads(3);
            let (b, sb) = lloyd_factored(&grid, &subs, &cfg, &opts);
            assert_eq!(a.assign, b.assign);
            assert_eq!(a.objective.to_bits(), b.objective.to_bits());
            assert_eq!(a.iters, b.iters);
            assert_eq!(sb.bounds, "elkan");
        });
    }

    #[test]
    fn elkan_within_scan_pruning_skips_and_stays_bitwise() {
        // The per-centroid skip inside the factored m-lookup loop must
        // leave assignments/objective bitwise identical to the naive
        // scan while actually pruning work: per-centroid bound tests
        // (bound_evals beyond the one-per-point Phase-1 test) and fewer
        // distance evaluations than the naive k-per-point count.
        let mut rng = SplitMix64::new(404);
        let (grid, subs) = random_problem(&mut rng, 400);
        let cfg = LloydConfig { k: 6, max_iters: 10, tol: 0.0, seed: 9 };
        let (a, sa) = lloyd_factored(&grid, &subs, &cfg, &EngineOpts::naive_serial());
        let opts = EngineOpts::pruned().with_bounds(BoundsPolicy::Elkan).with_threads(2);
        let (b, sb) = lloyd_factored(&grid, &subs, &cfg, &opts);
        assert_eq!(a.assign, b.assign);
        assert_eq!(a.objective.to_bits(), b.objective.to_bits());
        assert_eq!(a.iters, b.iters);
        // Phase 1 charges one bound test per point per bounded pass;
        // anything beyond that is the within-scan per-centroid tests.
        assert!(
            sb.bound_evals > sb.points * (sb.iters as u64 - 1),
            "no within-scan bound tests ran: {} bound evals over {} points × {} iters",
            sb.bound_evals,
            sb.points,
            sb.iters
        );
        assert!(sb.dist_evals < sa.dist_evals, "pruning saved nothing");
        assert!(sb.dist_evals_skipped > 0);
    }

    #[test]
    fn f32_tables_match_f32_naive_bitwise_and_f64_within_tolerance() {
        for_cases(10, |rng| {
            let n = 40 + rng.below(200) as usize;
            let (grid, subs) = random_problem(rng, n);
            let k = 1 + rng.below(5) as usize;
            let cfg = LloydConfig { k, max_iters: 8, tol: 0.0, seed: rng.next_u64() };
            let naive32 = EngineOpts::naive_serial().with_precision(Precision::F32);
            let pruned32 = EngineOpts::pruned().with_precision(Precision::F32).with_threads(2);
            let (a, _) = lloyd_factored(&grid, &subs, &cfg, &naive32);
            let (b, sb) = lloyd_factored(&grid, &subs, &cfg, &pruned32);
            assert_eq!(a.assign, b.assign);
            assert_eq!(a.objective.to_bits(), b.objective.to_bits());
            assert_eq!(sb.precision, "f32");
            // Tolerance vs f64 on a single assignment pass: identical
            // seed centroids (seeding distances are f64 in both modes),
            // so the objectives differ only by kernel rounding — robust
            // against near-tie argmin flips, whose contribution to the
            // objective is bounded by the same rounding.
            let cfg1 = LloydConfig { max_iters: 1, ..cfg };
            let (f64one, _) = lloyd_factored(&grid, &subs, &cfg1, &EngineOpts::pruned());
            let (f32one, _) = lloyd_factored(&grid, &subs, &cfg1, &pruned32);
            if f64one.objective > 1e-9 {
                let rel = (f64one.objective - f32one.objective).abs() / f64one.objective;
                assert!(
                    rel <= crate::cluster::engine::F32_OBJ_RTOL,
                    "factored f32 objective drifted {rel:.2e}"
                );
            }
        });
    }

    #[test]
    fn factored_drift_matches_bruteforce_on_grid_metric() {
        // ‖μ − μ'‖ from β tables must equal the metric the tables induce:
        // check against distances between indicator centroids, which are
        // exactly cell distances.
        for_cases(15, |rng| {
            let (grid, subs) = random_problem(rng, 12);
            let i = rng.below(grid.n() as u64) as usize;
            let j = rng.below(grid.n() as u64) as usize;
            let a = centroid_from_cell(&grid, &subs, i);
            let b = centroid_from_cell(&grid, &subs, j);
            let got = factored_dist2(&a, &b, &subs);
            let want = cell_dist2(&grid, &subs, i, j);
            crate::util::testkit::assert_close(got, want, 1e-9);
        });
    }

    #[test]
    fn warm_start_reuses_centroids_and_stale_shapes_fall_back() {
        for_cases(8, |rng| {
            let (grid, subs) = random_problem(rng, 80);
            let cfg = LloydConfig { k: 3, max_iters: 25, tol: 0.0, seed: rng.next_u64() };
            let (cold, _) = lloyd_factored(&grid, &subs, &cfg, &EngineOpts::pruned());
            // Warm start from converged centroids: no quality loss, fast stop.
            let warm_cfg = LloydConfig { tol: 1e-6, ..cfg };
            let (warm, _) = lloyd_factored_init(
                &grid,
                &subs,
                &warm_cfg,
                &EngineOpts::pruned(),
                Some(&cold.centroids),
            );
            assert!(warm.objective <= cold.objective * (1.0 + 1e-9));
            assert!(warm.iters <= 3, "warm start took {} iterations", warm.iters);
            // Wrong-k warm start must silently reseed and match the cold run.
            let stale = vec![cold.centroids[0].clone()]; // k=1 ≠ 3
            let (fresh, _) = lloyd_factored_init(
                &grid,
                &subs,
                &cfg,
                &EngineOpts::pruned(),
                Some(&stale),
            );
            assert_eq!(fresh.objective.to_bits(), cold.objective.to_bits());
            assert_eq!(fresh.assign, cold.assign);
        });
    }
}
