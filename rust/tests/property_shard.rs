//! Property tests for sharded Step 1–3 construction: for any shard count
//! the merged build must be **bitwise equal** to the unsharded one — on
//! the paper synthetics, on a cyclic (rewritten) FEQ, and for the
//! incremental per-shard `DeltaFaq` layer under delete-heavy streams.
//!
//! Bitwise equality holds because grid weights are tuple counts in the
//! ring ℤ: every per-shard weight is an exactly-represented f64 integer,
//! so per-shard accumulation followed by an exact merge addition lands on
//! the same bits as one serial pass (see `faq::shard` and
//! `incremental::sharded`).

use rkmeans::data::{Attr, Database, Relation, Schema, Value};
use rkmeans::faq::{grid_weights, shard_of, GidAssigner, GridTable};
use rkmeans::incremental::sharded::AssignerMap;
use rkmeans::incremental::{apply_to_db, DeltaFaq, DeltaLayer, ShardedDeltaFaq, TupleDelta};
use rkmeans::query::{Feq, Hypergraph};
use rkmeans::rkmeans::{ClusterOpts, RkPipeline, SubspaceOpts};
use rkmeans::synthetic::{retailer, retailer_trace, Dataset, Scale, TraceSpec};
use rkmeans::util::testkit::{assert_bitwise_result, for_cases};
use rkmeans::util::{FxHashMap, SplitMix64};

const SHARD_COUNTS: [usize; 3] = [1, 2, 7];

/// Assert two coresets carry the identical sparse grid, bit for bit.
fn assert_grid_bits(
    serial: &rkmeans::rkmeans::Coreset,
    sharded: &rkmeans::rkmeans::Coreset,
    label: &str,
) {
    assert_eq!(sharded.n(), serial.n(), "{label}: cell count");
    assert_eq!(sharded.grid.gids, serial.grid.gids, "{label}: gid vectors");
    for (i, (a, b)) in sharded.grid.weights.iter().zip(&serial.grid.weights).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{label}: weight of cell {i}");
    }
}

#[test]
fn from_shards_bitwise_on_paper_synthetics() {
    for ds in [Dataset::Retailer, Dataset::Favorita] {
        let db = ds.generate(Scale::tiny(), 17);
        let feq = ds.feq();
        let pipe = RkPipeline::plan(&db, &feq).unwrap();
        let marginals = pipe.marginals().unwrap();
        let subspaces = pipe.subspaces(&marginals, &SubspaceOpts::new(4)).unwrap();
        let serial = pipe.coreset(&subspaces).unwrap();
        for shards in SHARD_COUNTS {
            let label = format!("{} S={shards}", ds.name());
            let sharded = pipe.coreset_sharded(&subspaces, shards).unwrap();
            assert_grid_bits(&serial, &sharded, &label);
            // Step 4 over the merged coreset is therefore identical too.
            let a = serial.cluster(&ClusterOpts::new(5)).into_result();
            let b = sharded.cluster(&ClusterOpts::new(5)).into_result();
            assert_bitwise_result(&a, &b, &label);
        }
    }
}

/// A triangle query with payload features (cyclic: the planner rewrites
/// it, and the shard partition applies to the rewritten fact relation).
fn cyclic_setup() -> (Database, Feq) {
    let mut rng = SplitMix64::new(41);
    let mk = |name: &str, a: &str, b: &str, rng: &mut SplitMix64| {
        let mut r = Relation::new(
            name,
            Schema::new(vec![
                Attr::cat(a, 5),
                Attr::cat(b, 5),
                Attr::double(&format!("p_{name}")),
            ]),
        );
        for _ in 0..40 {
            r.push_row(&[
                Value::Cat(rng.below(5) as u32),
                Value::Cat(rng.below(5) as u32),
                Value::Double(rng.below(8) as f64),
            ]);
        }
        r
    };
    let mut db = Database::new();
    db.add(mk("r", "a", "b", &mut rng));
    db.add(mk("s", "b", "c", &mut rng));
    db.add(mk("t", "c", "a", &mut rng));
    let feq = Feq::with_features(&["r", "s", "t"], &["p_r", "p_s", "p_t", "a", "b", "c"]);
    (db, feq)
}

#[test]
fn from_shards_bitwise_on_cyclic_triangle() {
    let (db, feq) = cyclic_setup();
    assert!(Hypergraph::from_feq(&db, &feq).join_tree().is_err(), "should be cyclic");
    let pipe = RkPipeline::plan(&db, &feq).unwrap();
    assert!(pipe.was_rewritten());
    let marginals = pipe.marginals().unwrap();
    let subspaces = pipe.subspaces(&marginals, &SubspaceOpts::new(3)).unwrap();
    let serial = pipe.coreset(&subspaces).unwrap();
    for shards in SHARD_COUNTS {
        let sharded = pipe.coreset_sharded(&subspaces, shards).unwrap();
        assert_grid_bits(&serial, &sharded, &format!("triangle S={shards}"));
    }
}

/// Gid assigner: key (or value·4 for doubles) mod n.
struct ModAssigner {
    n: u32,
}
impl GidAssigner for ModAssigner {
    fn gid(&self, v: Value) -> u32 {
        let k = match v {
            Value::Double(x) => ((x * 4.0) as i64).rem_euclid(self.n as i64) as u64,
            other => other.key_u64(),
        };
        (k % self.n as u64) as u32
    }
    fn n_gids(&self) -> usize {
        self.n as usize
    }
}

const FEATURES: [&str; 6] = ["pay", "c0", "x0", "c1", "c2", "x2"];

fn assigners(n: u32) -> AssignerMap<'static> {
    let mut m: AssignerMap<'static> = FxHashMap::default();
    for a in FEATURES {
        m.insert(a.to_string(), Box::new(ModAssigner { n }));
    }
    m
}

/// The shadow database: per relation, a list of unit-weight tuples. The
/// oracle rebuilds a `Database` from it after every batch.
struct Shadow {
    schemas: Vec<(String, Schema)>,
    rows: Vec<Vec<Vec<Value>>>,
}

impl Shadow {
    fn to_db(&self) -> Database {
        let mut db = Database::new();
        for ((name, schema), rows) in self.schemas.iter().zip(&self.rows) {
            let mut rel = Relation::new(name, schema.clone());
            for r in rows {
                rel.push_row(r);
            }
            db.add(rel);
        }
        db
    }
}

/// Chain + star schema exercising multi-hop propagation: fact(j0, j1,
/// pay) ⋈ dim0(j0, c0, x0) ⋈ dim1(j1, j2, c1) ⋈ deep(j2, c2, x2).
fn random_instance(rng: &mut SplitMix64) -> (Shadow, Feq) {
    let dom = 3 + rng.below(4) as u32;
    let schemas = vec![
        (
            "fact".to_string(),
            Schema::new(vec![Attr::cat("j0", dom), Attr::cat("j1", dom), Attr::cat("pay", 6)]),
        ),
        (
            "dim0".to_string(),
            Schema::new(vec![Attr::cat("j0", dom), Attr::cat("c0", 5), Attr::double("x0")]),
        ),
        (
            "dim1".to_string(),
            Schema::new(vec![Attr::cat("j1", dom), Attr::cat("j2", dom), Attr::cat("c1", 5)]),
        ),
        (
            "deep".to_string(),
            Schema::new(vec![Attr::cat("j2", dom), Attr::cat("c2", 4), Attr::double("x2")]),
        ),
    ];
    let mut rows: Vec<Vec<Vec<Value>>> = vec![Vec::new(); 4];
    for (rel, row_list) in rows.iter_mut().enumerate() {
        let n = 8 + rng.below(15) as usize;
        for _ in 0..n {
            row_list.push(fresh_row(rel, dom, rng));
        }
    }
    let feq = Feq::with_features(&["fact", "dim0", "dim1", "deep"], &FEATURES);
    (Shadow { schemas, rows }, feq)
}

fn fresh_row(rel: usize, dom: u32, rng: &mut SplitMix64) -> Vec<Value> {
    let key = |rng: &mut SplitMix64| Value::Cat(rng.below(dom as u64) as u32);
    let frac = |rng: &mut SplitMix64| Value::Double(rng.below(8) as f64 * 0.25);
    match rel {
        0 => vec![key(rng), key(rng), Value::Cat(rng.below(6) as u32)],
        1 => vec![key(rng), Value::Cat(rng.below(5) as u32), frac(rng)],
        2 => vec![key(rng), key(rng), Value::Cat(rng.below(5) as u32)],
        3 => vec![key(rng), Value::Cat(rng.below(4) as u32), frac(rng)],
        _ => unreachable!(),
    }
}

/// Delete-heavy random batch (~70% deletes while tuples remain), applied
/// to the shadow as generated so deletes always reference live tuples.
/// Touches the partitioned fact relation and the broadcast dimension
/// relations alike.
fn delete_heavy_batch(shadow: &mut Shadow, dom: u32, rng: &mut SplitMix64) -> Vec<TupleDelta> {
    let n = 4 + rng.below(10) as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let rel = rng.below(4) as usize;
        let delete = rng.coin(0.7) && !shadow.rows[rel].is_empty();
        if delete {
            let i = rng.below(shadow.rows[rel].len() as u64) as usize;
            let vals = shadow.rows[rel].swap_remove(i);
            out.push(TupleDelta::delete(&shadow.schemas[rel].0, vals));
        } else {
            let vals = fresh_row(rel, dom, rng);
            shadow.rows[rel].push(vals.clone());
            out.push(TupleDelta::insert(&shadow.schemas[rel].0, vals));
        }
    }
    out
}

fn cells_bits(gt: &GridTable) -> FxHashMap<Vec<u32>, u64> {
    gt.cells.iter().map(|(g, w)| (g.clone(), w.to_bits())).collect()
}

#[test]
fn sharded_delta_bitwise_equals_scratch_under_delete_heavy_streams() {
    for_cases(10, |rng| {
        let (mut shadow, feq) = random_instance(rng);
        let dom = shadow.schemas[0].1.attr(0).domain;
        let kappa = 2 + rng.below(3) as u32;
        let shards = [2usize, 7][rng.below(2) as usize];

        let db0 = shadow.to_db();
        let tree = Hypergraph::from_feq(&db0, &feq).join_tree().expect("acyclic");
        let mut delta =
            ShardedDeltaFaq::init(&db0, &feq, &tree, shards, || assigners(kappa)).expect("init");
        assert_eq!(delta.shard_count(), shards);

        for round in 0..6 {
            let batch = delete_heavy_batch(&mut shadow, dom, rng);
            delta.apply(&batch, || assigners(kappa)).expect("apply");

            // Oracle: rebuild the database and run the batch evaluator.
            let db = shadow.to_db();
            let tree = Hypergraph::from_feq(&db, &feq).join_tree().expect("acyclic");
            let asg = assigners(kappa);
            let scratch = grid_weights(&db, &feq, &tree, &asg).expect("scratch");
            let inc = delta.grid_table();
            assert_eq!(inc.feature_names, scratch.feature_names, "round {round}");
            assert_eq!(
                cells_bits(&inc),
                cells_bits(&scratch),
                "round {round} S={shards}: sharded delta diverged from scratch"
            );
        }
        // Compaction after heavy churn must keep the merged grid intact.
        let before = cells_bits(&delta.grid_table());
        let _ = delta.compact();
        assert_eq!(before, cells_bits(&delta.grid_table()), "compaction changed the grid");
    });
}

/// Deletes route to the shard that holds their insert: draining every
/// fact tuple leaves all shards with exactly-zero fact mass and no
/// negative multiplicities (apply would fail at the root assert).
#[test]
fn draining_the_fact_relation_empties_every_shard() {
    let mut rng = SplitMix64::new(77);
    let (mut shadow, feq) = random_instance(&mut rng);
    let db0 = shadow.to_db();
    let tree = Hypergraph::from_feq(&db0, &feq).join_tree().expect("acyclic");
    let mut delta = ShardedDeltaFaq::init(&db0, &feq, &tree, 5, || assigners(3)).expect("init");
    assert!(delta.mass() > 0.0);

    while !shadow.rows[0].is_empty() {
        let take = (shadow.rows[0].len()).min(7);
        let batch: Vec<TupleDelta> = (0..take)
            .map(|_| {
                let i = rng.below(shadow.rows[0].len() as u64) as usize;
                TupleDelta::delete("fact", shadow.rows[0].swap_remove(i))
            })
            .collect();
        // Every delete hashes to the shard its insert landed on.
        for d in &batch {
            assert!(shard_of(&d.values, 5) < 5);
        }
        delta.apply(&batch, || assigners(3)).expect("apply");
    }
    assert_eq!(delta.mass(), 0.0, "empty join must have zero grid mass");
    assert_eq!(delta.n_cells(), 0);
}

/// The shared Retailer trace (delete-heavy variant) replays through the
/// sharded layer and stays bitwise-consistent with both a single
/// `DeltaFaq` and from-scratch evaluation, splice logs included.
#[test]
fn retailer_trace_delete_heavy_sharded_matches_single() {
    let mut db = retailer::generate(Scale::tiny(), 11);
    let feq = retailer::feq();
    let tree = Hypergraph::from_feq(&db, &feq).join_tree().expect("acyclic");
    let mk = || {
        let mut m: AssignerMap<'static> = FxHashMap::default();
        for f in &retailer::feq().features {
            m.insert(f.attr.clone(), Box::new(ModAssigner { n: 3 }) as Box<dyn GidAssigner>);
        }
        m
    };
    let mut single = DeltaFaq::init(&db, &feq, &tree, &mk()).expect("init single");
    let mut layer = DeltaLayer::init(&db, &feq, &tree, 4, mk).expect("init layer");
    assert_eq!(layer.shard_count(), 4);

    let trace =
        retailer_trace(&db, 29, TraceSpec { batches: 4, batch_size: 40, delete_frac: 0.5 });
    for (round, batch) in trace.iter().enumerate() {
        apply_to_db(&mut db, batch).expect("replay");
        single.apply(batch, &mk()).expect("apply single");
        layer.apply(batch, mk).expect("apply layer");
        assert_eq!(
            cells_bits(&single.grid_table()),
            cells_bits(&layer.grid_table()),
            "batch {round}: sharded layer diverged from single"
        );
        let scratch = grid_weights(&db, &feq, &tree, &mk()).expect("scratch");
        assert_eq!(cells_bits(&layer.grid_table()), cells_bits(&scratch), "batch {round}");
    }
}
