//! Synthetic workloads mirroring the paper's three datasets.
//!
//! The real Retailer dataset is proprietary and Favorita/Yelp are
//! multi-GB Kaggle dumps, so we generate schema-faithful synthetic
//! equivalents (documented in DESIGN.md §Substitutions): same relation
//! topology, same attribute types, same FD-chains, and Zipf-skewed fact
//! tables. Everything the paper measures — the `|X|`/`|D|` blowup, the
//! `|G|` vs κ curve, the step breakdown, the approximation ratio — is
//! driven by those structural properties, not by the literal values.
//!
//! Every generator is deterministic given `(Scale, seed)`.

pub mod favorita;
pub mod retailer;
pub mod trace;
pub mod yelp;

pub use trace::{favorita_trace, retailer_trace, TraceSpec};

/// Linear scale factor for dataset size. `Scale::tiny()` is for unit
/// tests; `Scale::small()` for integration tests; `Scale::bench()` for the
//  paper-table benchmarks; factors > 1 stress memory like the paper's
/// full-size runs.
#[derive(Clone, Copy, Debug)]
pub struct Scale {
    pub factor: f64,
}

impl Scale {
    /// Unit-test scale (hundreds of fact rows).
    pub fn tiny() -> Self {
        Scale { factor: 0.002 }
    }

    /// Integration-test scale (thousands of fact rows).
    pub fn small() -> Self {
        Scale { factor: 0.02 }
    }

    /// Bench scale (hundreds of thousands of fact rows).
    pub fn bench() -> Self {
        Scale { factor: 0.25 }
    }

    /// Paper-shaped scale (millions of fact rows).
    pub fn full() -> Self {
        Scale { factor: 1.0 }
    }

    /// Arbitrary factor.
    pub fn custom(factor: f64) -> Self {
        Scale { factor }
    }

    /// Scale a base count with a floor.
    pub(crate) fn n(&self, base: usize, min: usize) -> usize {
        ((base as f64 * self.factor) as usize).max(min)
    }
}

/// The three paper workloads, for CLI/bench dispatch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dataset {
    Retailer,
    Favorita,
    Yelp,
}

impl Dataset {
    /// All datasets in paper order.
    pub fn all() -> [Dataset; 3] {
        [Dataset::Retailer, Dataset::Favorita, Dataset::Yelp]
    }

    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Option<Dataset> {
        match s.to_ascii_lowercase().as_str() {
            "retailer" => Some(Dataset::Retailer),
            "favorita" => Some(Dataset::Favorita),
            "yelp" => Some(Dataset::Yelp),
            _ => None,
        }
    }

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            Dataset::Retailer => "Retailer",
            Dataset::Favorita => "Favorita",
            Dataset::Yelp => "Yelp",
        }
    }

    /// Generate the database.
    pub fn generate(&self, scale: Scale, seed: u64) -> crate::data::Database {
        match self {
            Dataset::Retailer => retailer::generate(scale, seed),
            Dataset::Favorita => favorita::generate(scale, seed),
            Dataset::Yelp => yelp::generate(scale, seed),
        }
    }

    /// The dataset's feature-extraction query.
    pub fn feq(&self) -> crate::query::Feq {
        match self {
            Dataset::Retailer => retailer::feq(),
            Dataset::Favorita => favorita::feq(),
            Dataset::Yelp => yelp::feq(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::Hypergraph;

    #[test]
    fn all_datasets_generate_valid_acyclic_feqs() {
        for ds in Dataset::all() {
            let db = ds.generate(Scale::tiny(), 7);
            let feq = ds.feq();
            feq.validate(&db).unwrap_or_else(|e| panic!("{}: {e}", ds.name()));
            Hypergraph::from_feq(&db, &feq)
                .join_tree()
                .unwrap_or_else(|e| panic!("{}: {e}", ds.name()));
            assert!(db.total_rows() > 0);
        }
    }

    #[test]
    fn generation_is_deterministic() {
        for ds in Dataset::all() {
            let a = ds.generate(Scale::tiny(), 3);
            let b = ds.generate(Scale::tiny(), 3);
            assert_eq!(a.total_rows(), b.total_rows());
            assert_eq!(a.total_bytes(), b.total_bytes());
        }
    }

    #[test]
    fn scale_monotone() {
        for ds in Dataset::all() {
            let small = ds.generate(Scale::tiny(), 1).total_rows();
            let bigger = ds.generate(Scale::custom(0.01), 1).total_rows();
            assert!(bigger >= small, "{}: {bigger} < {small}", ds.name());
        }
    }

    #[test]
    fn declared_fds_hold_in_data() {
        for ds in Dataset::all() {
            let db = ds.generate(Scale::tiny(), 9);
            for fd in &db.fds {
                assert!(
                    db.verify_fd(fd),
                    "{}: declared FD {} -> {} violated",
                    ds.name(),
                    fd.determinant,
                    fd.dependent
                );
            }
        }
    }

    #[test]
    fn parse_names() {
        assert_eq!(Dataset::parse("retailer"), Some(Dataset::Retailer));
        assert_eq!(Dataset::parse("FAVORITA"), Some(Dataset::Favorita));
        assert_eq!(Dataset::parse("nope"), None);
    }
}
