//! Bench gate — the CI regression check over the bench trajectory
//! (ROADMAP "bench trajectory in CI" item).
//!
//! Reads `BENCH_lloyd.json`, `BENCH_stream.json`, `BENCH_sweep.json`,
//! `BENCH_shard.json`, `BENCH_serve.json`, `BENCH_rpc.json` and
//! `BENCH_ingest.json` (as emitted by the smoke runs of `kernel_lloyd`,
//! `stream_ingest`, `k_sweep`, `shard_build`, `serve_load`, `rpc_load`
//! and `ingest_scale` earlier in the CI job) plus the committed baseline
//! `bench_baseline.json`, and **fails (exit 1)** when a tracked
//! throughput metric regresses more than the baseline's tolerance
//! (default 20 %) below its committed value:
//!
//! * `lloyd_retailer_pruned_speedup` — `speedup_vs_naive` of the
//!   `retailer-materialized` / `dense-pruned` record (machine-relative,
//!   so it is stable across CI hardware);
//! * `stream_patched_speedup` — `speedup_vs_rebuild` of the patched
//!   stream record (also a ratio);
//! * `stream_carry_speedup` — `speedup_vs_cold` of the patched stream
//!   record: the bound-carrying (Step-4 resume) arm vs. the cold warm
//!   start, guarding the planner's patched-path ratio;
//! * `sweep_shared_coreset_speedup` — `speedup_vs_independent` of the
//!   shared-coreset sweep record (also a ratio: one coreset + per-k
//!   Step 4 vs the full pipeline per k);
//! * `shard_build_speedup` — `speedup_vs_serial` of the `sharded-max`
//!   shard record: parallel Step-3 grid construction at S = available
//!   cores vs. the serial build (a ratio; grids are asserted
//!   bitwise-identical by the emitting bench, so only speed is gated);
//! * `serve_qps_speedup` — `speedup_vs_naive` of the `mesh` serve
//!   record: micro-batched assignment through the serving front vs.
//!   the un-batched one-call-per-request loop (a ratio);
//! * `serve_delta_bytes_ratio` — `delta_bytes_ratio` of the `delta`
//!   serve record: cumulative snapshot bytes / delta wire bytes over
//!   the bench's publishes (size, not speed — machine-independent);
//! * `rpc_qps_ratio` — `qps_ratio_vs_inproc` of the `rpc-1` rpc
//!   record: framed socket assignment through a real replica process
//!   vs. the in-process front (a ratio; crossing the process boundary
//!   costs throughput, the gate only holds the floor);
//! * `rpc_catchup_ok` — `catchup_ok` of the `rpc-3-churn` rpc record:
//!   1.0 when the replica killed and restarted mid-run converged back
//!   to the writer's latest version via byte-verified snapshot
//!   catch-up (a correctness bit, not a speed — any value below 1.0
//!   is a fault-recovery regression);
//! * `ingest_scale_speedup` — `speedup_vs_serial` of the `epochd-max`
//!   ingest record: P = S = available-parallelism multi-producer ingest
//!   through the epoch'd hub vs. the serial single-stream `DeltaFaq`
//!   apply (a ratio; the emitting bench asserts the final grids
//!   bitwise-identical across arms, so only throughput is gated).
//!
//! Baseline values are calibrated for the `--test` smoke shapes and set
//! conservatively; raise them as the engines get faster so the trajectory
//! ratchets. Env overrides: `RKMEANS_BASELINE`, `RKMEANS_BENCH_OUT`,
//! `RKMEANS_STREAM_OUT`, `RKMEANS_SWEEP_OUT`, `RKMEANS_SHARD_OUT`,
//! `RKMEANS_SERVE_OUT`, `RKMEANS_RPC_OUT`, `RKMEANS_INGEST_OUT` (same
//! paths the emitting benches use).

use rkmeans::util::json::{parse, Json};
use std::path::PathBuf;
use std::process::exit;

fn read_json(path: &PathBuf) -> Result<Json, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
    parse(&text).map_err(|e| format!("cannot parse {}: {e:?}", path.display()))
}

fn env_path(var: &str, default: &str) -> PathBuf {
    PathBuf::from(std::env::var(var).unwrap_or_else(|_| default.to_string()))
}

/// Find a record matching all `(key, value)` string fields.
fn find_record<'a>(doc: &'a Json, fields: &[(&str, &str)]) -> Option<&'a Json> {
    doc.get("records")?.as_arr()?.iter().find(|r| {
        fields
            .iter()
            .all(|(k, v)| r.get(k).and_then(|x| x.as_str()) == Some(*v))
    })
}

fn main() {
    let baseline_path = env_path("RKMEANS_BASELINE", "bench_baseline.json");
    let lloyd_path = env_path("RKMEANS_BENCH_OUT", "BENCH_lloyd.json");
    let stream_path = env_path("RKMEANS_STREAM_OUT", "BENCH_stream.json");
    let sweep_path = env_path("RKMEANS_SWEEP_OUT", "BENCH_sweep.json");
    let shard_path = env_path("RKMEANS_SHARD_OUT", "BENCH_shard.json");
    let serve_path = env_path("RKMEANS_SERVE_OUT", "BENCH_serve.json");
    let rpc_path = env_path("RKMEANS_RPC_OUT", "BENCH_rpc.json");
    let ingest_path = env_path("RKMEANS_INGEST_OUT", "BENCH_ingest.json");

    let mut failures: Vec<String> = Vec::new();
    let baseline = match read_json(&baseline_path) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("bench_gate: {e}");
            exit(1);
        }
    };
    let tolerance = baseline
        .get("tolerance")
        .and_then(|t| t.as_f64())
        .unwrap_or(0.2);
    let gate = |name: &str, actual: Option<f64>, failures: &mut Vec<String>| {
        let Some(base) = baseline.get("gate").and_then(|g| g.get(name)).and_then(|v| v.as_f64())
        else {
            println!("bench_gate: {name}: no baseline — skipped");
            return;
        };
        let floor = base * (1.0 - tolerance);
        match actual {
            Some(a) if a >= floor => {
                println!("bench_gate: {name}: {a:.3} >= floor {floor:.3} (baseline {base:.3}) ok")
            }
            Some(a) => failures.push(format!(
                "{name}: {a:.3} below floor {floor:.3} (baseline {base:.3}, tolerance {tolerance})"
            )),
            None => failures.push(format!("{name}: metric missing from bench output")),
        }
    };

    match read_json(&lloyd_path) {
        Ok(doc) => {
            let rec = find_record(
                &doc,
                &[("label", "retailer-materialized"), ("engine", "dense-pruned")],
            );
            gate(
                "lloyd_retailer_pruned_speedup",
                rec.and_then(|r| r.get("speedup_vs_naive")).and_then(|v| v.as_f64()),
                &mut failures,
            );
        }
        Err(e) => failures.push(e),
    }

    match read_json(&stream_path) {
        Ok(doc) => {
            let rec = find_record(&doc, &[("mode", "patched")]);
            gate(
                "stream_patched_speedup",
                rec.and_then(|r| r.get("speedup_vs_rebuild")).and_then(|v| v.as_f64()),
                &mut failures,
            );
            gate(
                "stream_carry_speedup",
                rec.and_then(|r| r.get("speedup_vs_cold")).and_then(|v| v.as_f64()),
                &mut failures,
            );
        }
        Err(e) => failures.push(e),
    }

    match read_json(&sweep_path) {
        Ok(doc) => {
            let rec = find_record(&doc, &[("mode", "shared-coreset")]);
            gate(
                "sweep_shared_coreset_speedup",
                rec.and_then(|r| r.get("speedup_vs_independent")).and_then(|v| v.as_f64()),
                &mut failures,
            );
        }
        Err(e) => failures.push(e),
    }

    match read_json(&shard_path) {
        Ok(doc) => {
            let rec = find_record(&doc, &[("mode", "sharded-max")]);
            gate(
                "shard_build_speedup",
                rec.and_then(|r| r.get("speedup_vs_serial")).and_then(|v| v.as_f64()),
                &mut failures,
            );
        }
        Err(e) => failures.push(e),
    }

    match read_json(&serve_path) {
        Ok(doc) => {
            let mesh = find_record(&doc, &[("mode", "mesh")]);
            gate(
                "serve_qps_speedup",
                mesh.and_then(|r| r.get("speedup_vs_naive")).and_then(|v| v.as_f64()),
                &mut failures,
            );
            let delta = find_record(&doc, &[("mode", "delta")]);
            gate(
                "serve_delta_bytes_ratio",
                delta.and_then(|r| r.get("delta_bytes_ratio")).and_then(|v| v.as_f64()),
                &mut failures,
            );
        }
        Err(e) => failures.push(e),
    }

    match read_json(&rpc_path) {
        Ok(doc) => {
            let one = find_record(&doc, &[("mode", "rpc-1")]);
            gate(
                "rpc_qps_ratio",
                one.and_then(|r| r.get("qps_ratio_vs_inproc")).and_then(|v| v.as_f64()),
                &mut failures,
            );
            let churn = find_record(&doc, &[("mode", "rpc-3-churn")]);
            gate(
                "rpc_catchup_ok",
                churn.and_then(|r| r.get("catchup_ok")).and_then(|v| v.as_f64()),
                &mut failures,
            );
        }
        Err(e) => failures.push(e),
    }

    match read_json(&ingest_path) {
        Ok(doc) => {
            let rec = find_record(&doc, &[("mode", "epochd-max")]);
            gate(
                "ingest_scale_speedup",
                rec.and_then(|r| r.get("speedup_vs_serial")).and_then(|v| v.as_f64()),
                &mut failures,
            );
        }
        Err(e) => failures.push(e),
    }

    if failures.is_empty() {
        println!("bench_gate: all tracked metrics within tolerance");
    } else {
        for f in &failures {
            eprintln!("bench_gate FAIL: {f}");
        }
        exit(1);
    }
}
