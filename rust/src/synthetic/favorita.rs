//! Synthetic **Favorita** (paper §5: 6 relations, 15 attrs, 1470 one-hot;
//! the public Kaggle grocery-forecasting dataset [17]).
//!
//! Schema:
//! * `sales(date, store, item, unit_sales, onpromotion)` — fact table;
//!   `unit_sales` has *many distinct values* (rounded to 2 decimals, like
//!   the paper's precision-reduction), which is what makes Step 2's 1-D DP
//!   dominate the runtime in Figure 3;
//! * `items(item, class, perishable, price)`;
//! * `stores(store, city, state, type, cluster)` with `store → city →
//!   state`;
//! * `transactions(date, store, txn_count)`;
//! * `oil(date, oil_price)`;
//! * `holiday(date, holiday_type)`.

use crate::data::{Attr, Database, Relation, Schema, Value};
use crate::query::Feq;
use crate::util::{SplitMix64, Zipf};

use super::Scale;

struct Dims {
    stores: usize,
    cities: usize,
    states: usize,
    items: usize,
    classes: usize,
    dates: usize,
    fact_rows: usize,
}

fn dims(scale: Scale) -> Dims {
    let stores = 54.max(scale.n(54, 10));
    let cities = (stores / 3).max(5);
    let states = (cities / 2).max(3);
    let items = scale.n(4000, 60);
    Dims {
        stores,
        cities,
        states,
        items,
        classes: (items / 12).max(8),
        dates: scale.n(365, 25),
        fact_rows: scale.n(2_500_000, 500),
    }
}

/// Generate the Favorita database at a scale.
pub fn generate(scale: Scale, seed: u64) -> Database {
    let d = dims(scale);
    let mut rng = SplitMix64::new(seed ^ 0xfa_0b_17_a5);
    let mut db = Database::new();

    // items
    let mut items = Relation::new(
        "items",
        Schema::new(vec![
            Attr::cat("item", d.items as u32),
            Attr::cat("class", d.classes as u32),
            Attr::cat("perishable", 2),
            Attr::double("price"),
        ]),
    );
    for i in 0..d.items {
        items.push_row(&[
            Value::Cat(i as u32),
            Value::Cat(rng.below(d.classes as u64) as u32),
            Value::Cat(u32::from(rng.coin(0.25))),
            Value::Double((rng.uniform(0.5, 40.0) * 100.0).round() / 100.0),
        ]);
    }
    db.add(items);

    // stores with the city -> state FD.
    let mut stores = Relation::new(
        "stores",
        Schema::new(vec![
            Attr::cat("store", d.stores as u32),
            Attr::cat("city", d.cities as u32),
            Attr::cat("state", d.states as u32),
            Attr::cat("type", 5),
            Attr::cat("cluster", 17),
        ]),
    );
    let city_of: Vec<u32> = (0..d.stores).map(|_| rng.below(d.cities as u64) as u32).collect();
    let state_of: Vec<u32> = (0..d.cities).map(|_| rng.below(d.states as u64) as u32).collect();
    for s in 0..d.stores {
        let c = city_of[s];
        stores.push_row(&[
            Value::Cat(s as u32),
            Value::Cat(c),
            Value::Cat(state_of[c as usize]),
            Value::Cat(rng.below(5) as u32),
            Value::Cat(rng.below(17) as u32),
        ]);
    }
    db.add(stores);
    db.add_fd("store", "city");
    db.add_fd("city", "state");

    // transactions: one row per (date, store).
    let mut tx = Relation::new(
        "transactions",
        Schema::new(vec![
            Attr::cat("date", d.dates as u32),
            Attr::cat("store", d.stores as u32),
            Attr::double("txn_count"),
        ]),
    );
    for t in 0..d.dates {
        for s in 0..d.stores {
            tx.push_row(&[
                Value::Cat(t as u32),
                Value::Cat(s as u32),
                Value::Double((800.0 + 400.0 * rng.normal()).round().max(0.0)),
            ]);
        }
    }
    db.add(tx);

    // oil: one price per date.
    let mut oil = Relation::new(
        "oil",
        Schema::new(vec![Attr::cat("date", d.dates as u32), Attr::double("oil_price")]),
    );
    let mut price = 60.0;
    for t in 0..d.dates {
        price = (price + rng.normal()).clamp(25.0, 110.0);
        oil.push_row(&[Value::Cat(t as u32), Value::Double((price * 100.0).round() / 100.0)]);
    }
    db.add(oil);

    // holiday: type per date (0 = none).
    let mut holiday = Relation::new(
        "holiday",
        Schema::new(vec![Attr::cat("date", d.dates as u32), Attr::cat("holiday_type", 4)]),
    );
    for t in 0..d.dates {
        let ty = if rng.coin(0.1) { 1 + rng.below(3) as u32 } else { 0 };
        holiday.push_row(&[Value::Cat(t as u32), Value::Cat(ty)]);
    }
    db.add(holiday);

    // sales: the fact table. unit_sales is lognormal-ish rounded to two
    // decimals — the high-distinct-count continuous attribute that makes
    // Step 2 dominate (paper Fig. 3 discussion).
    let mut sales = Relation::new(
        "sales",
        Schema::new(vec![
            Attr::cat("date", d.dates as u32),
            Attr::cat("store", d.stores as u32),
            Attr::cat("item", d.items as u32),
            Attr::double("unit_sales"),
            Attr::cat("onpromotion", 2),
        ]),
    );
    let item_zipf = Zipf::new(d.items, 1.05);
    for _ in 0..d.fact_rows {
        let item = item_zipf.sample(&mut rng);
        let promo = rng.coin(0.08);
        let mu = 1.2 + 1.5 / (1.0 + item as f64).ln_1p() + if promo { 0.7 } else { 0.0 };
        let units = (mu + 0.8 * rng.normal()).exp();
        sales.push_row(&[
            Value::Cat(rng.below(d.dates as u64) as u32),
            Value::Cat(rng.below(d.stores as u64) as u32),
            Value::Cat(item as u32),
            Value::Double((units * 100.0).round() / 100.0),
            Value::Cat(u32::from(promo)),
        ]);
    }
    db.add(sales);

    db
}

/// The Favorita FEQ (item/store/date ids are join keys, not features).
pub fn feq() -> Feq {
    Feq::with_features(
        &["sales", "items", "stores", "transactions", "oil", "holiday"],
        &[
            "unit_sales",
            "onpromotion",
            "class",
            "perishable",
            "price",
            "city",
            "state",
            "type",
            "cluster",
            "txn_count",
            "oil_price",
            "holiday_type",
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faq::output_size;
    use crate::query::Hypergraph;

    #[test]
    fn join_preserves_fact_rows() {
        let db = generate(Scale::tiny(), 1);
        let tree = Hypergraph::from_feq(&db, &feq()).join_tree().unwrap();
        let x = output_size(&db, &tree).unwrap();
        assert_eq!(x, db.get("sales").unwrap().n_rows() as f64);
    }

    #[test]
    fn unit_sales_has_many_distinct_values() {
        let db = generate(Scale::small(), 2);
        let sales = db.get("sales").unwrap();
        let col = sales.schema.index_of("unit_sales").unwrap();
        let mut seen = std::collections::HashSet::new();
        for r in 0..sales.n_rows() {
            seen.insert(sales.value(r, col).as_f64().to_bits());
        }
        // The whole point of Favorita: distinct count ~ O(rows).
        assert!(
            seen.len() > sales.n_rows() / 10,
            "only {} distinct of {}",
            seen.len(),
            sales.n_rows()
        );
    }
}
