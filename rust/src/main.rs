//! `rkmeans` — the Rk-means CLI (Layer-3 leader entrypoint).
//!
//! Subcommands:
//! * `gen`       — generate a synthetic dataset to CSV;
//! * `cluster`   — run Rk-means on a dataset (built-in or CSV directory),
//!   optionally exporting the serving model (`--model-out`);
//! * `sweep`     — k-sweep over one shared coreset (staged pipeline);
//! * `assign`    — serve a tuple from an exported model file, without any
//!   database;
//! * `baseline`  — run the materialize-then-cluster baseline;
//! * `tables`    — regenerate the paper's tables/figures;
//! * `serve`     — run the serving mesh: replicated models behind a
//!   micro-batching assign front under open-loop load, with a writer
//!   publishing centroid deltas (`rkmeans::serve`); with `--listen` it
//!   becomes the writer side of the multi-process tier, serving the
//!   socket RPC planes (`rkmeans::serve::rpc`) and broadcasting every
//!   published delta to subscribed replica processes;
//! * `replica`   — a replica process: fetch a byte-verified snapshot
//!   from the writer, serve assigns locally over its own socket, and
//!   follow the writer's delta stream with snapshot catch-up;
//! * `bench-rpc` — drive/probe/stop running rpc servers (the socket
//!   load generator and control-plane helper used by benches and CI);
//! * `stream`    — streaming-coordinator demo (ingest + periodic
//!   recluster; formerly `serve`, which forwards with a warning); with
//!   `--producers P` it runs the multi-producer ingest tier instead: P
//!   epoch-stamping producer threads over `--shards S` bounded shard
//!   queues, one published version per fully-drained epoch
//!   (`rkmeans::ingest`);
//! * `artifacts` — inspect/verify the AOT artifact manifest.
//!
//! The environment is offline (no clap); flags are parsed by a small
//! hand-rolled helper. Run `rkmeans help` for usage.

use anyhow::{anyhow, bail, Result};
use rkmeans::bench_harness::paper::{self, PaperCfg};
use rkmeans::cluster::{BoundsPolicy, EngineOpts, LloydConfig, Precision};
use rkmeans::coordinator::{Coordinator, CoordinatorConfig};
use rkmeans::coreset::SubspaceSolver;
use rkmeans::data::{csv, Value};
use rkmeans::incremental::{apply_to_db, IncrementalEngine, PlannerOpts, TupleDelta};
#[cfg(feature = "pjrt")]
use rkmeans::join::EmbedSpec;
use rkmeans::metrics::Metrics;
use rkmeans::rkmeans::{
    full_objective, materialize_and_cluster_capped, ClusterOpts, RkConfig, RkModel, RkPipeline,
    SubspaceOpts, SweepMode,
};
#[cfg(feature = "pjrt")]
use rkmeans::runtime::PjrtRuntime;
use rkmeans::serve::rpc::wire::{ROLE_REPLICA, ROLE_WRITER};
use rkmeans::serve::{
    fetch_snapshot, probe, run_open_loop, run_rpc_loop, send_stop, synth_rows, AssignFront,
    FrontOpts, LoadSpec, ModelMesh, Publisher, ReplicaSync, RpcOpts, RpcServer, SyncOpts,
};
use rkmeans::synthetic::{favorita_trace, retailer_trace, Dataset, Scale, TraceSpec};
use rkmeans::util::exec::shared_pool;
use rkmeans::util::{human_bytes, human_count, SplitMix64};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

const USAGE: &str = "\
rkmeans — fast k-means clustering for relational data (Rk-means, 2019)

USAGE:
  rkmeans gen       --dataset <retailer|favorita|yelp> [--scale F] [--seed N] --out DIR
  rkmeans cluster   (--dataset NAME | --db DIR) --k K [--kappa κ] [--rho ρ] [--scale F]
                    [--seed N] [--engine native|xla] [--bounds auto|hamerly|elkan]
                    [--precision f64|f32] [--threads N] [--shards S] [--eval-full]
                    [--model-out FILE]
  rkmeans sweep     (--dataset NAME | --db DIR) [--ks K1,K2,...] [--kappa κ] [--scale F]
                    [--seed N] [--bounds auto|hamerly|elkan] [--precision f64|f32]
                    [--threads N] [--shards S] [--ladder]
  rkmeans assign    --model FILE [--values \"v1,v2,...\"]
  rkmeans baseline  (--dataset NAME | --db DIR) --k K [--scale F] [--seed N] [--cap ROWS]
  rkmeans tables    [--which table1|table2|fig3|ablation-fd|ablation-sparse|kappa-sweep|all]
                    [--scale F] [--seed N] [--no-approx]
  rkmeans serve     (--dataset NAME | --db DIR) [--k K] [--scale F] [--seed N]
                    [--replicas R] [--clients C] [--requests N] [--batch B]
                    [--qps Q] [--publishes P]
                    [--listen ADDR] [--publish-ms MS] [--drop-every N]
  rkmeans replica   --connect ADDR [--listen ADDR] [--replicas R] [--batch B]
                    [--retries N] [--retry-base-ms MS] [--retry-cap-ms MS]
                    [--seed N]
  rkmeans bench-rpc --connect ADDR[,ADDR...] [--requests N] [--clients C]
                    [--qps Q] [--seed N] [--probe] [--stop]
  rkmeans stream    --dataset NAME [--scale F] [--rate N] [--batches N] [--k K]
                    [--shards S] [--producers P] [--spill-budget N]
  rkmeans artifacts [--dir DIR]
  rkmeans help
";

/// Minimal `--flag value` / `--flag` parser.
struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let val = if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    i += 1;
                    argv[i].clone()
                } else {
                    "true".to_string()
                };
                flags.insert(name.to_string(), val);
            } else {
                bail!("unexpected argument {a:?}");
            }
            i += 1;
        }
        Ok(Args { flags })
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    fn num<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("bad value for --{name}: {v:?}")),
        }
    }

    fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }
}

/// Plain one-line warning on stderr. CLI notices deliberately bypass
/// the telemetry/timer stack: no timestamps, no metrics — the text must
/// stay byte-stable so scripts (and the forwarding test) can pin it.
fn warn(msg: &str) {
    eprintln!("warning: {msg}");
}

fn load_db(args: &Args) -> Result<(rkmeans::data::Database, rkmeans::query::Feq, String)> {
    let scale = args.num("scale", 0.02f64)?;
    let seed = args.num("seed", 42u64)?;
    if let Some(name) = args.get("dataset") {
        let ds = Dataset::parse(name).ok_or_else(|| anyhow!("unknown dataset {name:?}"))?;
        Ok((ds.generate(Scale::custom(scale), seed), ds.feq(), ds.name().to_string()))
    } else if let Some(dir) = args.get("db") {
        let db = csv::read_database(&PathBuf::from(dir))?;
        // CSV databases join all relations on shared attribute names; the
        // feature list comes from a `_features.txt` sidecar.
        let rel_names: Vec<String> = db.relations().iter().map(|r| r.name.clone()).collect();
        let rels: Vec<&str> = rel_names.iter().map(|s| s.as_str()).collect();
        let feat_file = PathBuf::from(dir).join("_features.txt");
        if !feat_file.exists() {
            bail!("--db directories need a _features.txt listing the feature attributes");
        }
        let feats: Vec<String> = std::fs::read_to_string(feat_file)?
            .lines()
            .map(|l| l.trim().to_string())
            .filter(|l| !l.is_empty())
            .collect();
        let frefs: Vec<&str> = feats.iter().map(|s| s.as_str()).collect();
        let feq = rkmeans::query::Feq::with_features(&rels, &frefs);
        Ok((db, feq, dir.to_string()))
    } else {
        bail!("need --dataset or --db")
    }
}

fn cmd_gen(args: &Args) -> Result<()> {
    let (db, feq, name) = load_db(args)?;
    let out = PathBuf::from(args.get("out").ok_or_else(|| anyhow!("need --out DIR"))?);
    csv::write_database(&db, &out)?;
    let feats: Vec<String> = feq.features.iter().map(|f| f.attr.clone()).collect();
    std::fs::write(out.join("_features.txt"), feats.join("\n"))?;
    println!(
        "wrote {} ({} relations, {} rows, {}) to {}",
        name,
        db.relations().len(),
        human_count(db.total_rows()),
        human_bytes(db.total_bytes()),
        out.display()
    );
    Ok(())
}

/// Parse a `--bounds` value (absent = auto).
fn parse_bounds(v: Option<&str>) -> Result<BoundsPolicy> {
    match v {
        None | Some("auto") => Ok(BoundsPolicy::Auto),
        Some("hamerly") => Ok(BoundsPolicy::Hamerly),
        Some("elkan") => Ok(BoundsPolicy::Elkan),
        Some(other) => bail!("unknown bounds policy {other:?} (auto|hamerly|elkan)"),
    }
}

/// Parse a `--precision` value (absent = f64).
fn parse_precision(v: Option<&str>) -> Result<Precision> {
    match v {
        None | Some("f64") => Ok(Precision::F64),
        Some("f32") => Ok(Precision::F32),
        Some(other) => bail!("unknown precision {other:?} (f64|f32)"),
    }
}

fn cmd_cluster(args: &Args) -> Result<()> {
    let (db, feq, name) = load_db(args)?;
    let k = args.num("k", 10usize)?;
    let kappa = args.num("kappa", 0usize)?;
    let seed = args.num("seed", 42u64)?;
    let rho = args.num("rho", 0.0f64)?; // §3 regularizer (atom penalty)
    let bounds = parse_bounds(args.get("bounds"))?;
    let precision = parse_precision(args.get("precision"))?;
    let threads = args.num("threads", 0usize)?;
    let shards = args.num("shards", 1usize)?;
    let cfg = RkConfig::new(k)
        .with_kappa(kappa)
        .with_regularization(rho)
        .with_seed(seed)
        .with_bounds(bounds)
        .with_precision(precision)
        .with_threads(threads);

    let engine = args.get("engine").unwrap_or("native");
    let t0 = rkmeans::util::timer::now();
    let res = match engine {
        // Shard-parallel Steps 1–3 (bitwise-identical to the serial
        // build); `--shards 1` is the plain staged run.
        "native" if shards > 1 => {
            let pipe = RkPipeline::plan(&db, &feq)?;
            let marginals = pipe.marginals()?;
            let subspaces = pipe.subspaces(&marginals, &SubspaceOpts::from_config(&cfg))?;
            let coreset = pipe.coreset_sharded(&subspaces, shards)?;
            coreset.cluster(&ClusterOpts::from_config(&cfg)).into_result()
        }
        "native" => RkPipeline::plan(&db, &feq)?.run(&cfg)?.into_result(),
        #[cfg(feature = "pjrt")]
        "xla" => {
            let rt = PjrtRuntime::load(&PjrtRuntime::default_dir())?;
            rkmeans_xla(&db, &feq, &cfg, &rt)?
        }
        #[cfg(not(feature = "pjrt"))]
        "xla" => bail!("engine `xla` requires a build with `--features pjrt`"),
        other => bail!("unknown engine {other:?} (native|xla)"),
    };
    let total = t0.elapsed();

    println!("dataset           : {name}");
    println!("engine            : {engine}");
    if shards > 1 {
        println!("step1–3 shards    : {shards}");
    }
    println!("k / κ             : {} / {}", k, cfg.effective_kappa());
    println!("|G| grid cells    : {}", human_count(res.grid_points as u64));
    println!("grid mass (|X|)   : {}", human_count(res.grid_mass as u64));
    println!("step1 marginals   : {:?}", res.timings.step1_marginals);
    println!("step2 subspaces   : {:?}", res.timings.step2_subspaces);
    println!("step3 grid        : {:?}", res.timings.step3_grid);
    println!("step4 cluster     : {:?} ({} iters)", res.timings.step4_cluster, res.iters);
    println!(
        "step4 engine      : bounds={} precision={} (skip rate {:.1}%)",
        res.step4_stats.bounds,
        res.step4_stats.precision,
        100.0 * res.step4_stats.skip_rate()
    );
    println!("total             : {total:?}");
    println!("grid objective    : {:.6e}", res.objective_grid);
    println!("quantization cost : {:.6e}", res.quantization_cost);
    println!("upper bound L(X,C): {:.6e}", res.objective_upper_bound());
    if args.has("eval-full") {
        let full = full_objective(&db, &feq, &res)?;
        println!("full L(X,C)       : {full:.6e}");
    }
    if let Some(path) = args.get("model-out") {
        let bytes = RkModel::from_result(&res).to_bytes();
        std::fs::write(path, &bytes)?;
        println!("model out         : {path} ({} bytes; serve with `rkmeans assign`)", bytes.len());
    }
    Ok(())
}

/// k-sweep over one shared coreset: Steps 1–3 run once, Step 4 per k
/// (each result identical to an independent full run at that k).
fn cmd_sweep(args: &Args) -> Result<()> {
    let (db, feq, name) = load_db(args)?;
    let ks: Vec<usize> = args
        .get("ks")
        .unwrap_or("4,8,16,32")
        .split(',')
        .map(|s| {
            let s = s.trim();
            s.parse::<usize>().map_err(|_| anyhow!("bad k in --ks: {s:?}"))
        })
        .collect::<Result<Vec<usize>>>()?;
    let kappa = args.num("kappa", ks.iter().copied().max().unwrap_or(8))?;
    let seed = args.num("seed", 42u64)?;
    let threads = args.num("threads", 0usize)?;
    let shards = args.num("shards", 1usize)?;
    let engine = EngineOpts::default()
        .with_bounds(parse_bounds(args.get("bounds"))?)
        .with_precision(parse_precision(args.get("precision"))?)
        .with_threads(threads);
    // Ladder mode: warm-start each k from the previous k's centroids
    // (exactness vs. independent runs explicitly waived; see SweepMode).
    let mode = if args.has("ladder") { SweepMode::Ladder } else { SweepMode::Independent };

    let t0 = rkmeans::util::timer::now();
    let pipe = RkPipeline::plan(&db, &feq)?;
    let marginals = pipe.marginals()?;
    let subspaces = pipe.subspaces(&marginals, &SubspaceOpts::new(kappa))?;
    let coreset = pipe.coreset_sharded(&subspaces, shards)?;
    let shared = t0.elapsed();
    println!(
        "dataset {name}: shared steps 1–3 in {shared:?} (|G| = {} cells, κ = {kappa}{}{})",
        human_count(coreset.n() as u64),
        if shards > 1 { format!(", {shards} shards") } else { String::new() },
        if mode == SweepMode::Ladder { ", ladder seeding" } else { "" }
    );
    for model in
        coreset.sweep_with(&ks, &ClusterOpts::new(0).with_seed(seed).with_engine(engine), mode)
    {
        println!(
            "  k={:<4} objective={:.6e}  iters={:<3} step4={:?}",
            model.k(),
            model.objective_grid,
            model.iters,
            model.timings.step4_cluster
        );
    }
    Ok(())
}

/// Serve a tuple from an exported model file — no database involved.
fn cmd_assign(args: &Args) -> Result<()> {
    let path = args.get("model").ok_or_else(|| anyhow!("need --model FILE"))?;
    let bytes = std::fs::read(path)?;
    let model = RkModel::from_bytes(&bytes)?;
    let names: Vec<&str> = model.models.iter().map(|m| m.name.as_str()).collect();
    println!(
        "model: version {} k={} m={} (|G|={} cells, objective {:.6e})",
        model.version,
        model.k(),
        model.m(),
        model.grid_points,
        model.objective_grid
    );
    let Some(values) = args.get("values") else {
        println!(
            "pass --values \"v1,v2,...\" — {} feature values in FEQ order: {}",
            model.m(),
            names.join(", ")
        );
        return Ok(());
    };
    let vals = parse_tuple(&model, values)?;
    let (c, d) = model.assign_with_distance(&vals);
    println!("cluster {c} (squared distance {d:.6e})");
    Ok(())
}

/// Parse a comma-separated tuple using the model's per-subspace solver
/// kinds: continuous features parse as f64, categorical as u64 keys.
fn parse_tuple(model: &RkModel, text: &str) -> Result<Vec<Value>> {
    let toks: Vec<&str> = text.split(',').map(|t| t.trim()).collect();
    if toks.len() != model.m() {
        bail!("expected {} comma-separated feature values, got {}", model.m(), toks.len());
    }
    toks.iter()
        .zip(&model.models)
        .map(|(t, m)| match &m.solver {
            SubspaceSolver::Continuous(_) => t
                .parse::<f64>()
                .map(Value::Double)
                .map_err(|_| anyhow!("feature {:?}: bad number {t:?}", m.name)),
            SubspaceSolver::Categorical(_) => t
                .parse::<u64>()
                .map(|k| Value::Int(k as i64))
                .map_err(|_| anyhow!("feature {:?}: bad category key {t:?}", m.name)),
        })
        .collect()
}

/// Steps 1–3 native, Step 4 through the PJRT artifact (dense grid path).
#[cfg(feature = "pjrt")]
fn rkmeans_xla(
    db: &rkmeans::data::Database,
    feq: &rkmeans::query::Feq,
    cfg: &RkConfig,
    rt: &PjrtRuntime,
) -> Result<rkmeans::rkmeans::RkResult> {
    use rkmeans::coreset::{build_grid, grid_dense_embed, solve_subspaces};
    use rkmeans::faq::{full_join_counts, marginals};
    use rkmeans::query::Hypergraph;

    let tree = Hypergraph::from_feq(db, feq).join_tree()?;
    let mut res = rkmeans::rkmeans::rkmeans_with_tree(db, feq, &tree, cfg)?;

    let jc = full_join_counts(db, &tree)?;
    let margs = marginals(db, feq, &tree, &jc)?;
    let models = solve_subspaces(feq, &margs, cfg.effective_kappa())?;
    let (grid, _) = build_grid(db, feq, &tree, &models)?;
    let spec = EmbedSpec::from_feq(db, feq)?;
    let dense = grid_dense_embed(&grid, &models, &spec);
    let lcfg = LloydConfig { k: cfg.k, seed: cfg.seed, ..LloydConfig::new(cfg.k) };
    let t0 = rkmeans::util::timer::now();
    let xla = rt.lloyd(&dense, &grid.weights, spec.dims, &lcfg)?;
    println!(
        "xla step4         : {:?} ({} iters, objective {:.6e})",
        t0.elapsed(),
        xla.iters,
        xla.objective
    );
    res.timings.step4_cluster = t0.elapsed();
    res.objective_grid = xla.objective;
    Ok(res)
}

fn cmd_baseline(args: &Args) -> Result<()> {
    let (db, feq, name) = load_db(args)?;
    let k = args.num("k", 10usize)?;
    let seed = args.num("seed", 42u64)?;
    let cap = args.num("cap", 50_000_000u64)?;
    let cfg = LloydConfig { k, seed, ..LloydConfig::new(k) };
    let r = materialize_and_cluster_capped(&db, &feq, &cfg, cap)?;
    println!("dataset        : {name}");
    println!("|X| rows × D   : {} × {}", human_count(r.rows as u64), r.dims);
    println!("dense bytes    : {}", human_bytes(r.dense_bytes));
    println!("materialize    : {:?}", r.t_materialize);
    println!("one-hot embed  : {:?}", r.t_embed);
    println!("cluster        : {:?} ({} iters)", r.t_cluster, r.iters);
    println!("total          : {:?}", r.total_time());
    println!("objective      : {:.6e}", r.objective);
    Ok(())
}

fn cmd_tables(args: &Args) -> Result<()> {
    let scale = args.num("scale", 0.02f64)?;
    let mut cfg = PaperCfg::new(scale);
    cfg.seed = args.num("seed", 42u64)?;
    if args.has("no-approx") {
        cfg.eval_approx = false;
    }
    let which = args.get("which").unwrap_or("all");
    let all = which == "all";

    if all || which == "table1" {
        println!("{}", paper::table1(&cfg)?.render());
    }
    if all || which == "table2" {
        for ds in Dataset::all() {
            println!("{}", paper::table2(ds, &cfg)?.render());
        }
    }
    if all || which == "fig3" {
        for ds in Dataset::all() {
            println!("{}", paper::fig3(ds, &cfg)?.render());
        }
    }
    if all || which == "ablation-fd" {
        println!("{}", paper::ablation_fd(&cfg)?.render());
    }
    if all || which == "ablation-sparse" {
        for ds in Dataset::all() {
            println!("{}", paper::ablation_sparse(ds, 10, &cfg)?.render());
        }
    }
    if all || which == "kappa-sweep" {
        println!(
            "{}",
            paper::kappa_sweep(Dataset::Favorita, 20, &[2, 5, 10, 20], &cfg)?.render()
        );
    }
    Ok(())
}

/// The serving mesh under open-loop load (`rkmeans::serve`): `R`
/// hot-swappable replicas behind the micro-batching assign front, while
/// a writer replays a synthetic trace through the incremental engine
/// and ships each new version to the mesh as a verified centroid delta.
fn cmd_serve(args: &Args) -> Result<()> {
    // The pre-mesh streaming demo answered to `serve` with these flags;
    // forward old invocations so scripts keep working.
    let demo_flags = args.has("rate") || args.has("batches");
    let mesh_flags = args.has("requests")
        || args.has("clients")
        || args.has("replicas")
        || args.has("batch")
        || args.has("qps")
        || args.has("publishes")
        || args.has("listen");
    if demo_flags && !mesh_flags {
        warn(
            "the streaming-coordinator demo is now `rkmeans stream`; forwarding \
             (`rkmeans serve` runs the serving mesh — see `rkmeans help`)",
        );
        return cmd_stream(args);
    }

    // `--listen ADDR` turns the in-process mesh into the writer side of
    // the multi-process tier (`rkmeans::serve::rpc`).
    if let Some(listen) = args.get("listen") {
        return cmd_serve_rpc(args, listen);
    }

    let (mut db, feq, name) = load_db(args)?;
    let k = args.num("k", 5usize)?;
    let seed = args.num("seed", 42u64)?;
    let requests = args.num("requests", 20_000usize)?;
    let clients = args.num("clients", 4usize)?;
    let replicas = args.num("replicas", 2usize)?;
    let batch = args.num("batch", 64usize)?;
    let publishes = args.num("publishes", 3usize)?;
    let qps = match args.get("qps") {
        Some(v) => Some(v.parse::<f64>().map_err(|_| anyhow!("bad value for --qps: {v:?}"))?),
        None => None,
    };

    let metrics = Metrics::new();
    let mut engine = IncrementalEngine::new(
        &db,
        feq,
        RkConfig::new(k).with_seed(seed),
        PlannerOpts::default(),
        metrics.clone(),
    )?;
    let mesh = ModelMesh::new(engine.model(), replicas, metrics.clone());
    let fopts = FrontOpts { max_batch: batch, threads: 0 };
    let front = AssignFront::start(Arc::clone(&mesh), fopts, shared_pool());
    let rows = synth_rows(&mesh.model(0), 256, seed ^ 0x9e37_79b9);
    println!(
        "serving {name}: {replicas} replicas, {clients} clients × {requests} requests \
         (micro-batch ≤ {batch}), {publishes} publishes"
    );

    // Writer side: replay trace batches through the incremental engine,
    // publishing every version as a bit-verified delta while the load
    // generator below keeps the front busy — hot swaps under fire.
    let spec = TraceSpec::new(publishes, 512);
    let trace = match name.as_str() {
        "retailer" => retailer_trace(&db, seed + 1, spec),
        "favorita" => favorita_trace(&db, seed + 1, spec),
        _ => Vec::new(),
    };
    if trace.is_empty() && publishes > 0 {
        eprintln!("note: no synthetic trace for {name:?}; serving a single version");
    }
    let mut publisher = Publisher::new(Arc::clone(&mesh));
    let writer = std::thread::spawn(move || -> Result<()> {
        for deltas in &trace {
            apply_to_db(&mut db, deltas)?;
            let (decision, _) = engine.apply_batch(&db, deltas)?;
            let stats = publisher.publish(&engine.model())?;
            println!(
                "published v{} ({decision:?}): {} changed parts, {} B delta vs {} B snapshot \
                 ({:.1}x smaller)",
                stats.version,
                stats.changes,
                stats.delta_bytes,
                stats.snapshot_bytes,
                stats.bytes_ratio()
            );
        }
        Ok(())
    });

    let report = run_open_loop(&front, &rows, &LoadSpec { requests, clients, qps, seed });
    writer.join().expect("writer thread")?;
    front.shutdown();
    println!("{}", report.line("mesh"));
    println!("-- metrics --\n{}", metrics.render());
    Ok(())
}

/// `rkmeans serve --listen ADDR` — the writer side of the multi-process
/// tier: bind the socket planes, replay the synthetic trace through the
/// incremental engine, and broadcast every published delta to subscribed
/// replica processes. Serves until a control-plane STOP frame arrives.
///
/// Prints `rpc listening on <addr>` first (stdout is line-buffered, so
/// a parent process can scrape the bound port from a `--listen :0`
/// invocation), then one `published v<N> …` line per trace batch.
fn cmd_serve_rpc(args: &Args, listen: &str) -> Result<()> {
    let (mut db, feq, name) = load_db(args)?;
    let k = args.num("k", 5usize)?;
    let seed = args.num("seed", 42u64)?;
    let replicas = args.num("replicas", 2usize)?;
    let batch = args.num("batch", 64usize)?;
    let publishes = args.num("publishes", 3usize)?;
    let publish_ms = args.num("publish-ms", 200u64)?;
    let drop_every = args.num("drop-every", 0u64)?;

    let metrics = Metrics::new();
    let mut engine = IncrementalEngine::new(
        &db,
        feq,
        RkConfig::new(k).with_seed(seed),
        PlannerOpts::default(),
        metrics.clone(),
    )?;
    let mesh = ModelMesh::new(engine.model(), replicas, metrics.clone());
    let front = AssignFront::start(
        Arc::clone(&mesh),
        FrontOpts { max_batch: batch, threads: 0 },
        shared_pool(),
    );
    let listener = std::net::TcpListener::bind(listen)
        .map_err(|e| anyhow!("binding {listen}: {e}"))?;
    let opts = RpcOpts { drop_every, ..RpcOpts::default() };
    let server = RpcServer::start(listener, Arc::clone(&mesh), front.client(), ROLE_WRITER, opts)?;
    println!("rpc listening on {}", server.local_addr());
    println!(
        "serving {name} over rpc: {replicas} replica slots, micro-batch ≤ {batch}, \
         {publishes} publishes every {publish_ms} ms"
    );

    let spec = TraceSpec::new(publishes, 512);
    let trace = match name.as_str() {
        "retailer" => retailer_trace(&db, seed + 1, spec),
        "favorita" => favorita_trace(&db, seed + 1, spec),
        _ => Vec::new(),
    };
    if trace.is_empty() && publishes > 0 {
        warn(&format!("no synthetic trace for {name:?}; serving a single version"));
    }
    let mut publisher = Publisher::new(Arc::clone(&mesh));
    for deltas in &trace {
        // Pace publications so replicas get a window to subscribe (and,
        // under `--drop-every`, to notice the gap and catch up) between
        // versions — mirrors a production cadence, not a tight loop.
        std::thread::sleep(std::time::Duration::from_millis(publish_ms));
        apply_to_db(&mut db, deltas)?;
        let (decision, _) = engine.apply_batch(&db, deltas)?;
        let (stats, wire) = publisher.publish_wire(&engine.model())?;
        let subs = server.broadcast(&wire);
        println!(
            "published v{} ({decision:?}): {} changed parts, {} B delta → {subs} subscriber(s)",
            stats.version, stats.changes, stats.delta_bytes
        );
    }
    println!("publishing done at v{}; serving until STOP", publisher.version());
    server.wait();
    front.shutdown();
    println!("-- metrics --\n{}", metrics.render());
    Ok(())
}

/// `rkmeans replica --connect ADDR` — a replica process: fetch a
/// byte-verified snapshot from the writer (retrying while the writer
/// starts up), serve assigns over its own socket, and follow the
/// writer's delta stream with snapshot catch-up on version gaps.
fn cmd_replica(args: &Args) -> Result<()> {
    let connect =
        args.get("connect").ok_or_else(|| anyhow!("need --connect ADDR"))?.to_string();
    let listen = args.get("listen").unwrap_or("127.0.0.1:0");
    let replicas = args.num("replicas", 1usize)?;
    let batch = args.num("batch", 64usize)?;
    let retries = args.num("retries", 40u32)?;
    let base_ms = args.num("retry-base-ms", 20u64)?;
    let cap_ms = args.num("retry-cap-ms", 2000u64)?;
    let seed = args.num("seed", 42u64)?;

    // The writer may still be binding its socket; retry the initial
    // snapshot with the same bounded exponential backoff the sync loop
    // uses for reconnects.
    let mut model = None;
    for attempt in 0..retries.max(1) {
        match fetch_snapshot(&connect, std::time::Duration::from_secs(30)) {
            Ok(m) => {
                model = Some(m);
                break;
            }
            Err(e) => {
                if attempt + 1 == retries.max(1) {
                    bail!("fetching initial snapshot from {connect}: {e:#}");
                }
                let shift = attempt.min(6);
                let delay = (base_ms << shift).min(cap_ms);
                std::thread::sleep(std::time::Duration::from_millis(delay));
            }
        }
    }
    let model = model.expect("retry loop either set a model or bailed");
    println!("replica snapshot: v{} (k={}, m={})", model.version, model.k(), model.m());

    let metrics = Metrics::new();
    let mesh = ModelMesh::new(model, replicas, metrics.clone());
    let front = AssignFront::start(
        Arc::clone(&mesh),
        FrontOpts { max_batch: batch, threads: 0 },
        shared_pool(),
    );
    let listener = std::net::TcpListener::bind(listen)
        .map_err(|e| anyhow!("binding {listen}: {e}"))?;
    let server = RpcServer::start(
        listener,
        Arc::clone(&mesh),
        front.client(),
        ROLE_REPLICA,
        RpcOpts::default(),
    )?;
    println!("rpc listening on {}", server.local_addr());
    let sync_opts = SyncOpts { retries, base_ms, cap_ms, seed, ..SyncOpts::default() };
    let sync = ReplicaSync::start(connect, Arc::clone(&mesh), sync_opts);
    server.wait();
    sync.shutdown();
    front.shutdown();
    println!("-- metrics --\n{}", metrics.render());
    Ok(())
}

/// `rkmeans bench-rpc --connect ADDR[,ADDR…]` — drive the assign plane
/// of running rpc servers with the socket load generator, or (with
/// `--probe` / `--stop`) exercise the control plane from scripts.
fn cmd_bench_rpc(args: &Args) -> Result<()> {
    let connect =
        args.get("connect").ok_or_else(|| anyhow!("need --connect ADDR[,ADDR...]"))?;
    let addrs: Vec<String> = connect
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if addrs.is_empty() {
        bail!("--connect got no addresses: {connect:?}");
    }

    if args.has("stop") {
        for a in &addrs {
            send_stop(a)?;
            println!("stop sent to {a}");
        }
        return Ok(());
    }
    if args.has("probe") {
        for a in &addrs {
            let p = probe(a, std::time::Duration::from_secs(10))?;
            println!(
                "{a}: version={} role={} replicas={} catchups={} gaps={}",
                p.version, p.role, p.replicas, p.catchups, p.gaps
            );
        }
        return Ok(());
    }

    let requests = args.num("requests", 20_000usize)?;
    let clients = args.num("clients", 4usize)?;
    let seed = args.num("seed", 42u64)?;
    let qps = match args.get("qps") {
        Some(v) => Some(v.parse::<f64>().map_err(|_| anyhow!("bad value for --qps: {v:?}"))?),
        None => None,
    };
    let model = fetch_snapshot(&addrs[0], std::time::Duration::from_secs(30))?;
    let rows = synth_rows(&model, 256, seed ^ 0x9e37_79b9);
    println!(
        "bench-rpc: {clients} clients × {requests} requests over {} server(s), base v{}",
        addrs.len(),
        model.version
    );
    let out = run_rpc_loop(&addrs, &rows, &LoadSpec { requests, clients, qps, seed })?;
    println!("{}", out.report.line("rpc"));
    println!(
        "versions served: {:?}  lost={}  reconnects={}",
        out.versions, out.lost, out.reconnects
    );
    Ok(())
}

/// The streaming-coordinator demo (formerly `rkmeans serve`): random
/// fact tuples flow into the [`Coordinator`], reclustering per batch.
/// With `--producers P` (P > 1) the multi-producer ingest tier runs
/// instead: P epoch-stamping producer threads over `--shards S` bounded
/// shard queues, one published version per fully-drained epoch.
fn cmd_stream(args: &Args) -> Result<()> {
    let (db, feq, name) = load_db(args)?;
    let k = args.num("k", 5usize)?;
    let rate = args.num("rate", 2000usize)?; // tuples per batch/epoch
    let batches = args.num("batches", 5usize)?;
    let seed = args.num("seed", 42u64)?;
    let producers = args.num("producers", 1usize)?;
    let shards = args.num("shards", 1usize)?;

    let fact = feq.relations[0].clone();
    let fact_schema = db.get(&fact).expect("fact relation").schema.clone();
    let domains: Vec<u32> = fact_schema.attrs().iter().map(|a| a.domain).collect();
    let gen_vals = |rng: &mut SplitMix64| -> Vec<Value> {
        fact_schema
            .attrs()
            .iter()
            .zip(&domains)
            .map(|(a, &dom)| match a.ty {
                rkmeans::data::AttrType::Cat => Value::Cat(rng.below(dom.max(1) as u64) as u32),
                rkmeans::data::AttrType::Int => Value::Int(rng.range(0, 100)),
                rkmeans::data::AttrType::Double => {
                    Value::Double((rng.uniform(0.0, 50.0) * 100.0).round() / 100.0)
                }
            })
            .collect()
    };

    let mut cfg = CoordinatorConfig::new(RkConfig::new(k).with_seed(seed));
    cfg.recluster_every = rate;
    // Shard-parallel Step-3 state in the incremental planner (1 = off).
    cfg.planner.shards = shards;
    // Cold-key spilling budget for the delta states (0 = no spilling).
    cfg.planner.spill_budget = args.num("spill-budget", 0usize)?;

    if producers > 1 {
        cfg.producers = producers;
        cfg.shards = shards;
        let (coord, handles) = Coordinator::start_multi(db, feq, cfg)?;
        println!(
            "streaming {name}: {batches} epochs × {rate} tuples into {fact:?} \
             ({producers} producers, {shards} ingest shards)"
        );
        let per = rate.div_ceil(producers);
        std::thread::scope(|scope| {
            for h in handles {
                let fact = &fact;
                let gen_vals = &gen_vals;
                scope.spawn(move || {
                    let mut rng =
                        SplitMix64::new(seed ^ 0x9e37_79b9_7f4a_7c15u64.wrapping_mul(h.id() as u64 + 1));
                    for epoch in 1..=batches as u64 {
                        for _ in 0..per {
                            let d = TupleDelta::insert(fact.as_str(), gen_vals(&mut rng));
                            if h.send(epoch, d).is_err() {
                                return;
                            }
                        }
                        if h.seal(epoch).is_err() {
                            return;
                        }
                    }
                });
            }
            if let Some(u) = coord.recv_update(std::time::Duration::from_secs(120)) {
                println!(
                    "initial build: v{} — |G|={} objective={:.4e} ({:?})",
                    u.version, u.result.grid_points, u.result.objective_grid, u.elapsed
                );
            }
            for _ in 0..batches {
                if let Some(u) = coord.recv_update(std::time::Duration::from_secs(120)) {
                    println!(
                        "epoch {}: v{} after {} tuples — |G|={} objective={:.4e} ({:?}, {:?})",
                        u.epoch.unwrap_or(0),
                        u.version,
                        u.ingested,
                        u.result.grid_points,
                        u.result.objective_grid,
                        u.mode,
                        u.elapsed
                    );
                }
            }
        });
        println!("-- metrics --\n{}", coord.metrics().render());
        coord.shutdown()?;
        return Ok(());
    }

    let coord = Coordinator::start(db, feq, cfg);
    println!("streaming {name}: {batches} batches × {rate} tuples into {fact:?}");
    let mut rng = SplitMix64::new(seed);
    for b in 0..batches {
        for _ in 0..rate {
            coord.insert(&fact, gen_vals(&mut rng))?;
        }
        if let Some(u) = coord.recv_update(std::time::Duration::from_secs(120)) {
            println!(
                "batch {b}: v{} after {} tuples — |G|={} objective={:.4e} ({:?})",
                u.version, u.ingested, u.result.grid_points, u.result.objective_grid, u.elapsed
            );
        }
    }
    println!("-- metrics --\n{}", coord.metrics().render());
    coord.shutdown()?;
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_artifacts(_args: &Args) -> Result<()> {
    bail!("`rkmeans artifacts` requires a build with `--features pjrt`")
}

#[cfg(feature = "pjrt")]
fn cmd_artifacts(args: &Args) -> Result<()> {
    let dir = args.get("dir").map(PathBuf::from).unwrap_or_else(PjrtRuntime::default_dir);
    if !PjrtRuntime::available(&dir) {
        bail!("no artifacts at {} — run `make artifacts`", dir.display());
    }
    let rt = PjrtRuntime::load(&dir)?;
    println!("artifacts at {} ({} buckets):", dir.display(), rt.buckets().len());
    for b in rt.buckets() {
        println!(
            "  {:<36} entry={:<11} N={:<6} D={:<3} K={:<3} vmem≈{}",
            b.file,
            b.entry,
            b.n,
            b.d,
            b.k,
            human_bytes(b.vmem_bytes)
        );
    }
    // Smoke-execute the smallest bucket.
    let pts: Vec<f64> = (0..64).map(|i| (i % 8) as f64).collect();
    let w = vec![1.0; 32];
    let r = rt.lloyd(&pts, &w, 2, &LloydConfig::new(2))?;
    println!("smoke lloyd: objective={:.4} iters={}", r.objective, r.iters);
    Ok(())
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match argv.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    };
    let result = Args::parse(&rest).and_then(|args| match cmd {
        "gen" => cmd_gen(&args),
        "cluster" => cmd_cluster(&args),
        "sweep" => cmd_sweep(&args),
        "assign" => cmd_assign(&args),
        "baseline" => cmd_baseline(&args),
        "tables" => cmd_tables(&args),
        "serve" => cmd_serve(&args),
        "replica" => cmd_replica(&args),
        "bench-rpc" => cmd_bench_rpc(&args),
        "stream" => cmd_stream(&args),
        "artifacts" => cmd_artifacts(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(anyhow!("unknown command {other:?}\n{USAGE}")),
    });
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
