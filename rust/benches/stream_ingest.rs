//! Bench S1 — streaming maintenance: patched (Step-3 delta + Step-4
//! resume via the incremental planner) vs. full-pipeline rebuild per
//! batch, over a deterministic Retailer insert/delete trace
//! (`synthetic::retailer_trace`). Batch size is held ≤ 1 % of |D| — the
//! acceptance regime, where patched per-batch latency must beat the
//! rebuild by ≥ 5×. All arms replay the *same* trace onto clones of the
//! same database; only the maintenance work is timed (the shared
//! apply-to-db mirroring is not).
//!
//! Ablation arms (all planner-patched, same trace):
//! * `patched`        — bound carrying on, shared persistent pool (the
//!   production path; gated vs. rebuild **and** vs. `patched-cold`);
//! * `patched-cold`   — bound carrying off (`PlannerOpts::carry_state =
//!   false`): the pre-carry cold warm start;
//! * `patched-scoped` — carrying on, scoped-spawn executor instead of the
//!   persistent pool (the per-dispatch thread-spawn overhead arm).
//!
//! Results are written as one `BENCH_stream.json` document (schema: see
//! `bench_harness` docs; path override: `RKMEANS_STREAM_OUT`).
//!
//! `--test` (or `--smoke`) shrinks everything for CI smoke runs.
//! `RKMEANS_STREAM_SCALE` overrides the Retailer scale (default 0.02 ≈
//! 40k fact rows).

use rkmeans::bench_harness::{write_bench_stream, StreamBenchRecord};
use rkmeans::cluster::ExecutorKind;
use rkmeans::data::Database;
use rkmeans::incremental::{
    apply_to_db, IncrementalEngine, PlanDecision, PlannerOpts, TupleDelta,
};
use rkmeans::metrics::Metrics;
use rkmeans::query::{Feq, Hypergraph};
use rkmeans::rkmeans::{rkmeans_with_tree, RkConfig};
use rkmeans::synthetic::{retailer, retailer_trace, Scale, TraceSpec};
use std::path::PathBuf;
use std::time::Instant;

/// Replay the trace through the incremental planner with the given
/// options; returns the per-arm record and the final grid mass.
#[allow(clippy::too_many_arguments)]
fn planner_arm(
    db0: &Database,
    feq: &Feq,
    trace: &[Vec<TupleDelta>],
    rk: &RkConfig,
    planner: PlannerOpts,
    mode: &str,
    base_rows: usize,
    batch: usize,
) -> anyhow::Result<(StreamBenchRecord, f64)> {
    let mut db = db0.clone();
    // The initial full build is shared state every arm starts from; it is
    // not part of the per-batch latency.
    let mut engine = IncrementalEngine::new(&db, feq.clone(), rk.clone(), planner, Metrics::new())?;
    let mut times = Vec::with_capacity(trace.len());
    let mut last = None;
    for b in trace {
        apply_to_db(&mut db, b)?;
        let t0 = Instant::now();
        let (decision, res) = engine.apply_batch(&db, b)?;
        times.push(t0.elapsed().as_secs_f64());
        anyhow::ensure!(
            decision == PlanDecision::Patched,
            "planner rebuilt mid-trace; the {mode} arm is not comparable"
        );
        last = Some(res);
    }
    let last = last.expect("at least one batch");
    Ok((
        StreamBenchRecord::from_batches(
            "retailer-trace",
            mode,
            base_rows,
            batch,
            &times,
            last.grid_points,
            last.objective_grid,
        ),
        last.grid_mass,
    ))
}

fn main() -> anyhow::Result<()> {
    let test_mode = std::env::args().any(|a| a == "--test" || a == "--smoke");
    let scale: f64 = std::env::var("RKMEANS_STREAM_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if test_mode { 0.003 } else { 0.02 });
    let (k, batches) = if test_mode { (4usize, 3usize) } else { (8, 8) };

    let db = retailer::generate(Scale::custom(scale), 42);
    let feq = retailer::feq();
    let base_rows = db.total_rows() as usize;
    // The acceptance regime: batch ≤ 1 % of |D|.
    let batch = ((base_rows / 128).max(8)).min(base_rows / 100 + 8);
    let spec = TraceSpec { batches, batch_size: batch, delete_frac: 0.3 };
    let trace = retailer_trace(&db, 7, spec);
    let rk = RkConfig::new(k);
    println!(
        "stream workload: |D|={base_rows} rows (scale {scale}), batch={batch} \
         ({:.2}% of |D|) × {batches}, k={k}",
        100.0 * batch as f64 / base_rows as f64
    );

    // Arm 1: full rebuild per batch (the coordinator's old loop).
    let (rebuild_rec, rebuild_mass) = {
        let mut db = db.clone();
        let tree = Hypergraph::from_feq(&db, &feq).join_tree()?;
        let mut times = Vec::with_capacity(batches);
        let mut last = None;
        for b in &trace {
            apply_to_db(&mut db, b)?;
            let t0 = Instant::now();
            let res = rkmeans_with_tree(&db, &feq, &tree, &rk)?;
            times.push(t0.elapsed().as_secs_f64());
            last = Some(res);
        }
        let last = last.expect("at least one batch");
        (
            StreamBenchRecord::from_batches(
                "retailer-trace",
                "rebuild",
                base_rows,
                batch,
                &times,
                last.grid_points,
                last.objective_grid,
            ),
            last.grid_mass,
        )
    };
    println!("{}", rebuild_rec.line());

    let lenient = PlannerOpts {
        drift_threshold: 1.1,
        max_patch_fraction: 1.0,
        rebuild_every: 0,
        max_join_churn: f64::INFINITY,
        ..PlannerOpts::default()
    };

    // Ablation arms: bound-carry off, and scoped-spawn executor.
    let (cold_rec, cold_mass) = planner_arm(
        &db,
        &feq,
        &trace,
        &rk,
        PlannerOpts { carry_state: false, ..lenient.clone() },
        "patched-cold",
        base_rows,
        batch,
    )?;
    println!("{}", cold_rec.line());

    let (scoped_rec, scoped_mass) = planner_arm(
        &db,
        &feq,
        &trace,
        &rk.clone().with_executor(ExecutorKind::Scoped),
        lenient.clone(),
        "patched-scoped",
        base_rows,
        batch,
    )?;
    println!("{}", scoped_rec.line());

    // The production arm: carrying + shared pool, gated against both the
    // rebuild and the carry-disabled arm.
    let (patched_rec, patched_mass) =
        planner_arm(&db, &feq, &trace, &rk, lenient, "patched", base_rows, batch)?;
    let patched_rec =
        patched_rec.with_speedup_vs(&rebuild_rec).with_carry_speedup_vs(&cold_rec);
    println!("{}", patched_rec.line());

    // Sanity: every arm ends at the same join mass (|X| is
    // Step-2-model-independent; grids can differ slightly because
    // patching freezes the Step-2 models while a rebuild re-solves them),
    // and the patched arms are exactly equivalent.
    for (label, mass) in
        [("patched-cold", cold_mass), ("patched-scoped", scoped_mass), ("patched", patched_mass)]
    {
        anyhow::ensure!(
            (mass - rebuild_mass).abs() <= 1e-6 * rebuild_mass.abs().max(1.0),
            "final grid mass diverged: {label} {mass} vs rebuild {rebuild_mass}"
        );
    }
    anyhow::ensure!(
        patched_rec.objective.to_bits() == cold_rec.objective.to_bits()
            && patched_rec.objective.to_bits() == scoped_rec.objective.to_bits(),
        "patched arms diverged: carrying and the executor must never change results"
    );

    let speedup = patched_rec.speedup_vs_rebuild.unwrap_or(0.0);
    let carry = patched_rec.speedup_vs_cold.unwrap_or(0.0);
    let records = vec![rebuild_rec, cold_rec, scoped_rec, patched_rec];
    let out = PathBuf::from(
        std::env::var("RKMEANS_STREAM_OUT").unwrap_or_else(|_| "BENCH_stream.json".to_string()),
    );
    write_bench_stream(&out, &records)?;
    println!("wrote {} records to {}", records.len(), out.display());
    println!(
        "patched vs rebuild per-batch latency: {speedup:.2}× (acceptance target ≥ 5× at \
         batch ≤ 1% of |D|); bound carrying vs cold warm start: {carry:.2}×"
    );
    Ok(())
}
