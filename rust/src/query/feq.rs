//! Feature-extraction query (FEQ) specification.

use crate::data::{AttrType, Database};
use anyhow::{bail, Result};

/// A feature of the clustering instance: an attribute of the FEQ output,
/// with an optional non-uniform weight (Huang-style mixed-type weighting,
/// paper §2.3/§4.1 — the weight scales that subspace's contribution to the
/// squared distance).
#[derive(Clone, Debug)]
pub struct FeatureSpec {
    pub attr: String,
    pub weight: f64,
}

impl FeatureSpec {
    /// Unit-weight feature.
    pub fn new(attr: &str) -> Self {
        FeatureSpec { attr: attr.to_string(), weight: 1.0 }
    }

    /// Feature with an explicit weight.
    pub fn weighted(attr: &str, weight: f64) -> Self {
        FeatureSpec { attr: attr.to_string(), weight }
    }
}

/// A feature-extraction query: the natural join of `relations`, projected
/// onto `features`. Join variables are attributes shared by ≥2 relations.
#[derive(Clone, Debug)]
pub struct Feq {
    pub relations: Vec<String>,
    pub features: Vec<FeatureSpec>,
}

impl Feq {
    /// Build an FEQ over the given relations and features.
    pub fn new(relations: &[&str], features: Vec<FeatureSpec>) -> Self {
        Feq {
            relations: relations.iter().map(|s| s.to_string()).collect(),
            features,
        }
    }

    /// Convenience: unit-weight features by name.
    pub fn with_features(relations: &[&str], features: &[&str]) -> Self {
        Self::new(relations, features.iter().map(|f| FeatureSpec::new(f)).collect())
    }

    /// Number of features (the paper's `d`, pre-one-hot).
    pub fn n_features(&self) -> usize {
        self.features.len()
    }

    /// Attributes shared by at least two participating relations — the join
    /// variables of the natural join.
    pub fn join_vars(&self, db: &Database) -> Vec<String> {
        let mut counts: Vec<(String, usize)> = Vec::new();
        for rname in &self.relations {
            let rel = db.get(rname).expect("relation exists");
            for a in rel.schema.attrs() {
                match counts.iter_mut().find(|(n, _)| n == &a.name) {
                    Some((_, c)) => *c += 1,
                    None => counts.push((a.name.clone(), 1)),
                }
            }
        }
        counts.into_iter().filter(|(_, c)| *c >= 2).map(|(n, _)| n).collect()
    }

    /// The relation that "owns" each feature: the first participating
    /// relation whose schema contains the attribute. Every per-attribute
    /// computation (marginals, quotient columns) happens at the owner so a
    /// shared join attribute is counted exactly once.
    pub fn owner_of(&self, db: &Database, attr: &str) -> Option<usize> {
        self.relations
            .iter()
            .position(|rname| db.get(rname).map(|r| r.schema.contains(attr)).unwrap_or(false))
    }

    /// Validate against a database: relations exist, features exist in some
    /// participating relation, feature weights are positive, and no
    /// continuous attribute is used as a join variable.
    pub fn validate(&self, db: &Database) -> Result<()> {
        if self.relations.is_empty() {
            bail!("FEQ has no relations");
        }
        for rname in &self.relations {
            if db.get(rname).is_none() {
                bail!("FEQ references unknown relation {rname:?}");
            }
        }
        for f in &self.features {
            if self.owner_of(db, &f.attr).is_none() {
                bail!("feature {:?} not found in any participating relation", f.attr);
            }
            if !(f.weight > 0.0) {
                bail!("feature {:?} has non-positive weight {}", f.attr, f.weight);
            }
        }
        for jv in self.join_vars(db) {
            for rname in &self.relations {
                let rel = db.get(rname).expect("validated above");
                if let Some(idx) = rel.schema.index_of(&jv) {
                    if rel.schema.attr(idx).ty == AttrType::Double {
                        bail!("continuous attribute {jv:?} used as a join variable");
                    }
                }
            }
        }
        Ok(())
    }

    /// Feature weight by attribute name (1.0 if unlisted).
    pub fn feature_weight(&self, attr: &str) -> f64 {
        self.features
            .iter()
            .find(|f| f.attr == attr)
            .map(|f| f.weight)
            .unwrap_or(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Attr, Relation, Schema, Value};

    fn db() -> Database {
        let mut t = Relation::new(
            "fact",
            Schema::new(vec![Attr::cat("store", 2), Attr::cat("sku", 3), Attr::double("units")]),
        );
        t.push_row(&[Value::Cat(0), Value::Cat(1), Value::Double(2.0)]);
        let mut s = Relation::new(
            "stores",
            Schema::new(vec![Attr::cat("store", 2), Attr::cat("city", 2)]),
        );
        s.push_row(&[Value::Cat(0), Value::Cat(1)]);
        let mut db = Database::new();
        db.add(t);
        db.add(s);
        db
    }

    #[test]
    fn join_vars_and_owner() {
        let db = db();
        let feq = Feq::with_features(&["fact", "stores"], &["store", "sku", "units", "city"]);
        assert_eq!(feq.join_vars(&db), vec!["store".to_string()]);
        assert_eq!(feq.owner_of(&db, "city"), Some(1));
        assert_eq!(feq.owner_of(&db, "store"), Some(0));
        assert_eq!(feq.owner_of(&db, "nope"), None);
        feq.validate(&db).unwrap();
    }

    #[test]
    fn validate_rejects_bad_queries() {
        let db = db();
        assert!(Feq::with_features(&["missing"], &["x"]).validate(&db).is_err());
        assert!(Feq::with_features(&["fact"], &["city"]).validate(&db).is_err());
        let mut feq = Feq::with_features(&["fact"], &["sku"]);
        feq.features[0].weight = 0.0;
        assert!(feq.validate(&db).is_err());
    }

    #[test]
    fn rejects_continuous_join_var() {
        let mut db = db();
        // Add a relation sharing the continuous attribute name "units".
        let mut bad = Relation::new("bad", Schema::new(vec![Attr::double("units")]));
        bad.push_row(&[Value::Double(1.0)]);
        db.add(bad);
        let feq = Feq::with_features(&["fact", "bad"], &["sku"]);
        assert!(feq.validate(&db).is_err());
    }

    #[test]
    fn feature_weights_default_to_one() {
        let feq = Feq::new(&["fact"], vec![FeatureSpec::weighted("sku", 2.0)]);
        assert_eq!(feq.feature_weight("sku"), 2.0);
        assert_eq!(feq.feature_weight("other"), 1.0);
    }
}
