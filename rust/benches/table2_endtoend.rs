//! Bench T2 — regenerates paper Table 2: end-to-end runtime and relative
//! approximation, Rk-means vs materialize+cluster, for k ∈ {5,10,20,50}
//! with κ = k and the κ < k columns — followed by the Step-4 engine
//! ablation (naive vs. bounds-pruned, factored and dense) so the pruning
//! speedup and skip rates are visible in the same invocation.
//!
//! `RKMEANS_BENCH_SCALE` (default 0.05) controls dataset size;
//! `RKMEANS_BENCH_KS` (comma-separated) overrides the k grid.

use rkmeans::bench_harness::paper::{engine_ablation, table2, PaperCfg};
use rkmeans::synthetic::Dataset;

fn main() -> anyhow::Result<()> {
    let scale: f64 =
        std::env::var("RKMEANS_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.05);
    let mut cfg = PaperCfg::new(scale);
    if let Ok(ks) = std::env::var("RKMEANS_BENCH_KS") {
        cfg.ks = ks.split(',').filter_map(|s| s.trim().parse().ok()).collect();
    }
    for ds in Dataset::all() {
        let t0 = std::time::Instant::now();
        println!("{}", table2(ds, &cfg)?.render());
        println!("[{} table2 generated in {:?}]", ds.name(), t0.elapsed());

        // Step-4 engine paths on this dataset's coreset, pruned vs naive.
        let k = cfg.ks.iter().copied().max().unwrap_or(20);
        let (tbl, records) = engine_ablation(ds, k, 10, &cfg)?;
        println!("{}", tbl.render());
        for r in &records {
            println!("{}", r.line());
        }
        println!();
    }
    Ok(())
}
