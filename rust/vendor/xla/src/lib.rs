//! Compile-only stub of the `xla-rs` PJRT bindings.
//!
//! The offline build environment has no PJRT shared library, but the
//! `runtime` module (behind the `pjrt` feature) still needs the `xla`
//! crate's surface to typecheck. This stub mirrors exactly the API used by
//! `rkmeans::runtime`; every entry point fails at *runtime* with a clear
//! message, so `cargo build --features pjrt` succeeds anywhere while real
//! execution requires swapping this path dependency for an actual
//! `xla-rs` checkout (edit the `xla` entry in `rust/Cargo.toml`, or add a
//! `[patch]` section pointing at the real crate).

use std::fmt;

/// Error type matching `xla::Error`'s role (displayable, boxable).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// `Result` alias used by all stub entry points.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: this build links the compile-only PJRT stub (rust/vendor/xla); point the \
         `xla` dependency at a real xla-rs checkout to run AOT artifacts"
    )))
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    /// CPU client.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    /// Compile a computation for this client.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given argument literals.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// A device buffer returned by execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Parsed HLO module text.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an `.hlo.txt` artifact.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A host-side literal (dense array value).
#[derive(Clone)]
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    /// Destructure a 3-tuple literal.
    pub fn to_tuple3(&self) -> Result<(Literal, Literal, Literal)> {
        unavailable("Literal::to_tuple3")
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}
