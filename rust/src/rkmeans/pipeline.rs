//! The staged Rk-means pipeline: Algorithm 1 as four artifact-passing
//! stages instead of one monolithic call.
//!
//! The paper's four steps have well-defined intermediate artifacts, and
//! real deployments want to *reuse* them: a κ-sweep re-solves Step 2 over
//! the same marginals, a k-sweep (paper Table 2) re-runs only Step 4 over
//! the same coreset, and a serving replica needs nothing but the final
//! model. The staged API says this in types:
//!
//! | stage | call | artifact | reusable across |
//! |---|---|---|---|
//! | plan    | [`RkPipeline::plan`]      | join tree (+ acyclic rewrite) | everything below |
//! | Step 1  | [`RkPipeline::marginals`] | [`Marginals`]   | κ and ρ choices |
//! | Step 2  | [`RkPipeline::subspaces`] | [`SubspaceSet`] | grid rebuilds |
//! | Step 3  | [`RkPipeline::coreset`]   | [`Coreset`]     | every k (and warm starts) |
//! | Step 4  | [`Coreset::cluster`] / [`Coreset::sweep`] | [`RkModel`] | serving replicas |
//!
//! Each stage returns an owned, inspectable artifact that later stages
//! borrow; nothing is recomputed behind the caller's back. The staged
//! path is **exact**: running all four stages with the options derived
//! from an [`RkConfig`] produces bitwise-identical results to the
//! one-shot [`rkmeans`](crate::rkmeans::rkmeans) convenience wrapper
//! (which is now a thin shim over this module).
//!
//! Step 3 can also run shard-parallel: [`RkPipeline::coreset_sharded`]
//! partitions the fact relation into value-hashed horizontal shards,
//! builds the per-shard grids as independent jobs on the shared worker
//! pool, and merges them by exact weight addition
//! ([`Coreset::from_shards`]) — grid weights are tuple counts in the
//! ring ℤ, so the merged coreset is bitwise-identical to the serial
//! build.
//!
//! ```no_run
//! use rkmeans::rkmeans::{ClusterOpts, RkPipeline, SubspaceOpts};
//! use rkmeans::synthetic::{retailer, Scale};
//!
//! let db = retailer::generate(Scale::small(), 42);
//! let feq = retailer::feq();
//!
//! let pipe = RkPipeline::plan(&db, &feq).unwrap();
//! let marginals = pipe.marginals().unwrap();                     // Step 1, once
//! let subspaces = pipe.subspaces(&marginals, &SubspaceOpts::new(16)).unwrap();
//! let coreset = pipe.coreset(&subspaces).unwrap();               // Step 3, once
//!
//! // k-sweep over the shared coreset: Steps 1–3 are amortized.
//! for model in coreset.sweep(&[4, 8, 16, 32], &ClusterOpts::new(0)) {
//!     println!("k={}: objective {:.4e}", model.k(), model.objective_grid);
//! }
//! ```

use super::model::RkModel;
use super::{RkConfig, StepTimings};
use crate::cluster::engine::factored::{centroid_from_cell, factored_dist2};
use crate::cluster::sparse_lloyd::{cell_dist2, SparseGrid, Subspace};
use crate::cluster::{
    sparse_lloyd_resume_with, sparse_lloyd_warm_with, CentroidCoord, EngineOpts, EngineState,
    LloydConfig,
};
use crate::coreset::{
    build_grid, build_grid_sharded, solve_subspaces_regularized, sparse_from_table, SubspaceModel,
};
use crate::data::Database;
use crate::faq::{full_join_counts, marginals as faq_marginals, GridTable, Marginal};
use crate::join::ensure_acyclic;
use crate::query::{Feq, Hypergraph, JoinTree};
use crate::util::{FxHashMap, SplitMix64};
use anyhow::Result;
use std::time::Duration;

/// Step-2 options: the per-subspace centroid budget κ and the §3
/// regularizer's atom penalty ρ.
#[derive(Clone, Debug)]
pub struct SubspaceOpts {
    /// Per-subspace centroids κ (κ < k trades approximation for a
    /// smaller grid; paper Table 2, right).
    pub kappa: usize,
    /// Atom penalty ρ for regularized Rk-means (0 = off).
    pub regularization: f64,
}

impl SubspaceOpts {
    /// Unregularized Step 2 with the given κ.
    pub fn new(kappa: usize) -> Self {
        SubspaceOpts { kappa, regularization: 0.0 }
    }

    /// Enable the §3 regularizer with atom penalty ρ.
    pub fn with_regularization(mut self, rho: f64) -> Self {
        self.regularization = rho;
        self
    }

    /// The Step-2 options an [`RkConfig`] implies (κ = k when unset).
    pub fn from_config(cfg: &RkConfig) -> Self {
        SubspaceOpts { kappa: cfg.effective_kappa(), regularization: cfg.regularization }
    }
}

/// Step-4 options: the Lloyd configuration plus the engine selection.
#[derive(Clone, Debug)]
pub struct ClusterOpts {
    /// Number of clusters k.
    pub k: usize,
    /// Lloyd iteration cap.
    pub max_iters: usize,
    /// Relative-improvement stopping tolerance.
    pub tol: f64,
    /// Seed for k-means++ seeding.
    pub seed: u64,
    /// Step-4 engine options (bounds pruning, thread count).
    pub engine: EngineOpts,
}

impl ClusterOpts {
    /// Paper-default Step-4 configuration (matches [`RkConfig::new`]).
    pub fn new(k: usize) -> Self {
        ClusterOpts { k, max_iters: 50, tol: 1e-6, seed: 0xC0FFEE, engine: EngineOpts::default() }
    }

    /// Override the seeding RNG seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Override the Lloyd iteration cap.
    pub fn with_max_iters(mut self, max_iters: usize) -> Self {
        self.max_iters = max_iters;
        self
    }

    /// Override the stopping tolerance.
    pub fn with_tol(mut self, tol: f64) -> Self {
        self.tol = tol;
        self
    }

    /// Override the engine options.
    pub fn with_engine(mut self, engine: EngineOpts) -> Self {
        self.engine = engine;
        self
    }

    /// The Step-4 options an [`RkConfig`] implies (the config's bounds
    /// policy, kernel precision, thread clamp and executor kind carry
    /// into the engine, so they also flow through every warm-started path
    /// — the incremental planner's `cluster_warm`, sweeps, the
    /// coordinator).
    pub fn from_config(cfg: &RkConfig) -> Self {
        ClusterOpts {
            k: cfg.k,
            max_iters: cfg.max_iters,
            tol: cfg.tol,
            seed: cfg.seed,
            engine: EngineOpts::default()
                .with_bounds(cfg.bounds)
                .with_precision(cfg.precision)
                .with_threads(cfg.threads)
                .with_executor(cfg.executor.executor()),
        }
    }

    fn lloyd(&self) -> LloydConfig {
        LloydConfig { k: self.k, max_iters: self.max_iters, tol: self.tol, seed: self.seed }
    }
}

/// Step-1 artifact: per-attribute marginal weights `w_j` (Eq. 3) over the
/// unmaterialized join, plus the output size `|X|`. Reused across every
/// κ/ρ choice in [`RkPipeline::subspaces`].
#[derive(Clone, Debug)]
pub struct Marginals {
    margs: FxHashMap<String, Marginal>,
    /// Weighted join-output size `|X|`.
    pub output_size: f64,
    /// Step-1 wall-clock.
    pub elapsed: Duration,
}

impl Marginals {
    /// Marginal for a feature attribute.
    pub fn get(&self, attr: &str) -> Option<&Marginal> {
        self.margs.get(attr)
    }

    /// Number of per-attribute marginals held.
    pub fn n_attributes(&self) -> usize {
        self.margs.len()
    }
}

/// Step-2 artifact: the per-subspace optimal models (geometry +
/// assigners). Reused across grid rebuilds; feed to
/// [`RkPipeline::coreset`].
#[derive(Clone, Debug)]
pub struct SubspaceSet {
    /// One solved model per FEQ feature, in feature order.
    pub models: Vec<SubspaceModel>,
    /// The κ these models were solved for.
    pub kappa: usize,
    /// The atom penalty ρ used (0 = unregularized).
    pub regularization: f64,
    /// Step-2 wall-clock.
    pub elapsed: Duration,
    /// Step-1 wall-clock inherited from the [`Marginals`] artifact, so
    /// downstream artifacts can assemble a classic [`StepTimings`].
    step1_elapsed: Duration,
}

impl SubspaceSet {
    /// Coreset quantization error Σ_j Step-2 cost (`W₂²(Q, P_in)`, Eq. 9).
    pub fn quantization_cost(&self) -> f64 {
        self.models.iter().map(|m| m.cost).sum()
    }

    /// Number of subspaces m.
    pub fn n_subspaces(&self) -> usize {
        self.models.len()
    }
}

/// Step-3 artifact: the sparse weighted grid coreset in factored form,
/// together with the subspace geometry and models Step 4 and the serving
/// layer need. Standalone: clustering and k-sweeps never touch the
/// database again.
#[derive(Clone, Debug)]
pub struct Coreset {
    /// The grid coreset `G` in component-id form.
    pub grid: SparseGrid,
    /// Per-subspace component geometry for the factored engine.
    pub subspaces: Vec<Subspace>,
    /// The Step-2 models the grid was built with (assigners for serving).
    pub models: Vec<SubspaceModel>,
    /// Step-3 wall-clock.
    pub elapsed: Duration,
    /// Steps 1–3 wall-clock, for assembling classic [`StepTimings`].
    timings123: StepTimings,
}

impl Coreset {
    /// Wrap an externally built grid (e.g. the incremental planner's
    /// delta-patched grid table) as a coreset artifact. Timings are zero:
    /// the builder did the work elsewhere.
    pub fn from_parts(
        grid: SparseGrid,
        subspaces: Vec<Subspace>,
        models: Vec<SubspaceModel>,
    ) -> Coreset {
        Coreset {
            grid,
            subspaces,
            models,
            elapsed: Duration::default(),
            timings123: StepTimings::default(),
        }
    }

    /// Number of non-zero grid cells `|G|`.
    pub fn n(&self) -> usize {
        self.grid.n()
    }

    /// True when the coreset has no cells (empty join output).
    pub fn is_empty(&self) -> bool {
        self.grid.n() == 0
    }

    /// Total grid mass (= weighted `|X|`).
    pub fn mass(&self) -> f64 {
        self.grid.weights.iter().sum()
    }

    /// Number of subspaces m.
    pub fn m(&self) -> usize {
        self.models.len()
    }

    /// Coreset quantization error Σ_j Step-2 cost.
    pub fn quantization_cost(&self) -> f64 {
        self.models.iter().map(|m| m.cost).sum()
    }

    /// Step 4: weighted k-means over this coreset on the bounds-pruned
    /// chunk-parallel engine. Bitwise-identical to what the one-shot
    /// [`rkmeans`](crate::rkmeans::rkmeans) produces for the same
    /// configuration.
    pub fn cluster(&self, opts: &ClusterOpts) -> RkModel {
        self.cluster_warm(opts, None)
    }

    /// [`Coreset::cluster`] with an optional warm start: previous
    /// factored centroids seed the run in place of k-means++ (shape
    /// mismatches fall back to fresh seeding). The incremental planner's
    /// patch path re-clusters delta-patched grids this way in a couple of
    /// Lloyd iterations. `init = None` is bitwise-identical to
    /// [`Coreset::cluster`].
    pub fn cluster_warm(
        &self,
        opts: &ClusterOpts,
        init: Option<&[Vec<CentroidCoord>]>,
    ) -> RkModel {
        let t0 = crate::util::timer::now();
        let (res, stats) =
            sparse_lloyd_warm_with(&self.grid, &self.subspaces, &opts.lloyd(), &opts.engine, init);
        let mut timings = self.timings123.clone();
        timings.step4_cluster = t0.elapsed();
        RkModel::assemble(
            self.models.clone(),
            res.centroids,
            res.objective,
            self.quantization_cost(),
            self.grid.n(),
            self.mass(),
            res.iters,
            timings,
            stats,
            0,
        )
    }

    /// [`Coreset::cluster_warm`] with cross-run state carry: always
    /// returns the run's carryable
    /// [`EngineState`](crate::cluster::EngineState) alongside the model,
    /// and accepts the previous run's state so the warm-started Step 4
    /// reuses its assignments and bounds instead of a full first scan
    /// (the incremental planner's patch path, after splicing the state
    /// across the grid edit). The model is bitwise-identical to
    /// [`Coreset::cluster_warm`] with the same arguments.
    ///
    /// Resume rides on the warm start: the state is dropped (cold warm
    /// start) when the effective k or the cell count no longer match it —
    /// but a state whose centroid hash disagrees with the actual starting
    /// centroids is a caller bug and panics loudly in the engine.
    pub fn cluster_resume(
        &self,
        opts: &ClusterOpts,
        init: Option<&[Vec<CentroidCoord>]>,
        state: Option<&EngineState>,
    ) -> (RkModel, EngineState) {
        let t0 = crate::util::timer::now();
        let k_eff = opts.k.min(self.grid.n()).max(1);
        let state = state.filter(|st| st.k() == k_eff && st.n() == self.grid.n());
        let (res, stats, next) = sparse_lloyd_resume_with(
            &self.grid,
            &self.subspaces,
            &opts.lloyd(),
            &opts.engine,
            init,
            state,
        );
        let mut timings = self.timings123.clone();
        timings.step4_cluster = t0.elapsed();
        let model = RkModel::assemble(
            self.models.clone(),
            res.centroids,
            res.objective,
            self.quantization_cost(),
            self.grid.n(),
            self.mass(),
            res.iters,
            timings,
            stats,
            0,
        );
        (model, next)
    }

    /// k-sweep over the shared coreset (paper Table 2): one model per k,
    /// each identical to an independent full-pipeline run at that k —
    /// but Steps 1–3 are paid once, not `ks.len()` times. `opts.k` is
    /// ignored; every other option applies to each run. Equivalent to
    /// [`Coreset::sweep_with`] in [`SweepMode::Independent`].
    pub fn sweep(&self, ks: &[usize], opts: &ClusterOpts) -> Vec<RkModel> {
        self.sweep_with(ks, opts, SweepMode::Independent)
    }

    /// [`Coreset::sweep`] with an explicit [`SweepMode`].
    /// [`SweepMode::Ladder`] warm-starts each k from the previous model's
    /// centroids (plus a k-means++-style D² fill for the new slots) via
    /// the existing [`Coreset::cluster_warm`] plumbing — typically far
    /// fewer Lloyd iterations per k, at the cost of the
    /// exactness-vs-independent-runs contract (see [`SweepMode`]).
    pub fn sweep_with(&self, ks: &[usize], opts: &ClusterOpts, mode: SweepMode) -> Vec<RkModel> {
        let mut out: Vec<RkModel> = Vec::with_capacity(ks.len());
        let mut prev: Option<Vec<Vec<CentroidCoord>>> = None;
        for &k in ks {
            let o = ClusterOpts { k, ..opts.clone() };
            let model = match (&mode, &prev) {
                (SweepMode::Ladder, Some(p)) if p.len() <= k && !p.is_empty() => {
                    let init = ladder_seed(&self.grid, &self.subspaces, p, k, o.seed);
                    self.cluster_warm(&o, Some(&init))
                }
                _ => self.cluster(&o),
            };
            if mode == SweepMode::Ladder {
                prev = Some(model.centroids.clone());
            }
            out.push(model);
        }
        out
    }

    /// Merge two coreset shards built with the **same Step-2 models**
    /// over a partition of the fact relation: cell-wise weight addition
    /// on the shared per-dimension grid. Equivalent to
    /// [`Coreset::from_shards`] on the pair.
    pub fn merge(self, other: Coreset) -> Result<Coreset> {
        Coreset::from_shards(vec![self, other])
    }

    /// Merge any number of coreset shards into the coreset of the union
    /// database: the shards' sparse grids are summed cell-wise and
    /// re-sorted into the canonical cell order, under the shared Step-2
    /// models (which every shard must agree on — same subspaces, same
    /// κ_j).
    ///
    /// Grid weights are join-output tuple counts (ring ℤ), so with
    /// integer multiplicities below 2⁵³ the merged weights are **bitwise
    /// identical** to a single unsharded [`RkPipeline::coreset`] build
    /// over the union — `tests/property_shard.rs` pins this for shard
    /// counts 1, 2 and 7. Step-3 elapsed time is the max over shards
    /// (the shards build in parallel); Step-1/2 timings are inherited
    /// from the first shard.
    pub fn from_shards(mut shards: Vec<Coreset>) -> Result<Coreset> {
        anyhow::ensure!(!shards.is_empty(), "cannot merge zero coreset shards");
        let names: Vec<String> = shards[0].models.iter().map(|m| m.name.clone()).collect();
        for s in &shards[1..] {
            let other: Vec<String> = s.models.iter().map(|m| m.name.clone()).collect();
            anyhow::ensure!(
                other == names,
                "coreset shards disagree on subspaces: {other:?} vs {names:?}"
            );
            for (a, b) in shards[0].models.iter().zip(&s.models) {
                anyhow::ensure!(
                    a.n_gids() == b.n_gids(),
                    "coreset shards disagree on κ for subspace {:?} ({} vs {})",
                    a.name,
                    b.n_gids(),
                    a.n_gids()
                );
            }
        }
        let models = std::mem::take(&mut shards[0].models);
        let step3 = shards.iter().map(|s| s.elapsed).max().unwrap_or_default();
        let mut timings123 = shards[0].timings123.clone();
        timings123.step3_grid = step3;
        let tables: Vec<GridTable> =
            shards.iter().map(|s| grid_to_table(&s.grid, &names)).collect();
        let merged = GridTable::merge(tables)?;
        let (grid, subspaces) = sparse_from_table(merged, &models);
        Ok(Coreset { grid, subspaces, models, elapsed: step3, timings123 })
    }
}

/// A [`SparseGrid`] back in [`GridTable`] form (the merge substrate).
fn grid_to_table(grid: &SparseGrid, feature_names: &[String]) -> GridTable {
    let m = grid.m;
    GridTable {
        feature_names: feature_names.to_vec(),
        cells: grid
            .weights
            .iter()
            .enumerate()
            .map(|(i, &w)| (grid.gids[i * m..(i + 1) * m].to_vec(), w))
            .collect(),
    }
}

/// How [`Coreset::sweep_with`] seeds each k.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SweepMode {
    /// Fresh k-means++ seeding per k: every swept model is
    /// **bitwise-identical** to an independent full-pipeline run at that
    /// k (the exactness contract `tests/staged_pipeline.rs` pins).
    #[default]
    Independent,
    /// Warm-started ladder: each k seeds from the previous k's converged
    /// centroids, with the remaining slots filled by D² (k-means++-style)
    /// sampling over the grid. Cuts sweep time when `ks` is ascending
    /// (e.g. k = 2i seeded from k = i), but the
    /// exactness-vs-independent-runs contract is **explicitly waived**:
    /// a laddered model generally differs (usually for the better at
    /// equal iteration budgets) from a fresh run at the same k. A k
    /// smaller than its predecessor falls back to fresh seeding.
    Ladder,
}

/// D² fill for the ladder sweep: carry `prev` (≤ k centroids) and sample
/// the remaining slots k-means++-style over the grid cells (a cell enters
/// as its indicator-coefficient centroid, exactly like engine seeding and
/// reseeds).
fn ladder_seed(
    grid: &SparseGrid,
    subspaces: &[Subspace],
    prev: &[Vec<CentroidCoord>],
    k: usize,
    seed: u64,
) -> Vec<Vec<CentroidCoord>> {
    let n = grid.n();
    let mut cents = prev.to_vec();
    cents.truncate(k.min(n));
    let mut rng = SplitMix64::new(seed);
    // Distance of every cell to its nearest carried centroid: a cell is
    // an indicator-coefficient centroid, so the factored metric applies.
    let mut mind: Vec<f64> = (0..n)
        .map(|i| {
            let cell = centroid_from_cell(grid, subspaces, i);
            cents
                .iter()
                .map(|c| factored_dist2(&cell, c, subspaces))
                .fold(f64::INFINITY, f64::min)
        })
        .collect();
    while cents.len() < k.min(n) {
        let scores: Vec<f64> = mind.iter().zip(&grid.weights).map(|(&d, &w)| d * w).collect();
        let total: f64 = scores.iter().sum();
        let next = if total > 0.0 {
            rng.weighted_index(&scores, total)
        } else {
            // All residual mass already covered (duplicate-heavy grids):
            // fall back to weight sampling.
            let tw: f64 = grid.weights.iter().sum();
            rng.weighted_index(&grid.weights, tw)
        };
        cents.push(centroid_from_cell(grid, subspaces, next));
        for i in 0..n {
            let dd = cell_dist2(grid, subspaces, i, next);
            if dd < mind[i] {
                mind[i] = dd;
            }
        }
    }
    cents
}

/// The staged pipeline handle: a validated FEQ plus its join tree (with
/// the cyclic-FEQ rewrite applied when necessary). See module docs.
pub struct RkPipeline<'a> {
    db: &'a Database,
    feq: &'a Feq,
    /// Acyclic rewrite of `(db, feq)` when the input FEQ is cyclic.
    rewritten: Option<(Database, Feq)>,
    tree: JoinTree,
}

impl<'a> RkPipeline<'a> {
    /// Validate the FEQ and build the join tree. Cyclic FEQs are
    /// rewritten via [`ensure_acyclic`] (relation merging), exactly as
    /// the one-shot [`rkmeans`](crate::rkmeans::rkmeans) does.
    pub fn plan(db: &'a Database, feq: &'a Feq) -> Result<RkPipeline<'a>> {
        feq.validate(db)?;
        match Hypergraph::from_feq(db, feq).join_tree() {
            Ok(tree) => Ok(RkPipeline { db, feq, rewritten: None, tree }),
            Err(_) => {
                let (db2, feq2) = ensure_acyclic(db, feq)?;
                let tree = Hypergraph::from_feq(&db2, &feq2).join_tree()?;
                Ok(RkPipeline { db, feq, rewritten: Some((db2, feq2)), tree })
            }
        }
    }

    /// Plan with a caller-provided join tree (no validation, no rewrite)
    /// — the staged analog of
    /// [`rkmeans_with_tree`](crate::rkmeans::rkmeans_with_tree).
    pub fn with_tree(db: &'a Database, feq: &'a Feq, tree: &JoinTree) -> RkPipeline<'a> {
        RkPipeline { db, feq, rewritten: None, tree: tree.clone() }
    }

    /// The effective database (the acyclic rewrite when one was needed).
    pub fn db(&self) -> &Database {
        self.rewritten.as_ref().map(|(d, _)| d).unwrap_or(self.db)
    }

    /// The effective FEQ (the acyclic rewrite when one was needed).
    pub fn feq(&self) -> &Feq {
        self.rewritten.as_ref().map(|(_, f)| f).unwrap_or(self.feq)
    }

    /// The join tree the stages run over.
    pub fn tree(&self) -> &JoinTree {
        &self.tree
    }

    /// True when planning rewrote a cyclic FEQ into an acyclic one.
    pub fn was_rewritten(&self) -> bool {
        self.rewritten.is_some()
    }

    /// Step 1: per-attribute marginal weights `w_j` via two-pass message
    /// passing. The artifact is reusable across every κ/ρ choice.
    pub fn marginals(&self) -> Result<Marginals> {
        let t0 = crate::util::timer::now();
        let jc = full_join_counts(self.db(), &self.tree)?;
        let margs = faq_marginals(self.db(), self.feq(), &self.tree, &jc)?;
        Ok(Marginals { margs, output_size: jc.total, elapsed: t0.elapsed() })
    }

    /// Step 2: optimal per-subspace clustering of the marginals
    /// (regularized when `opts.regularization > 0`).
    pub fn subspaces(&self, marginals: &Marginals, opts: &SubspaceOpts) -> Result<SubspaceSet> {
        let t0 = crate::util::timer::now();
        let models = solve_subspaces_regularized(
            self.feq(),
            &marginals.margs,
            opts.kappa,
            opts.regularization,
        )?;
        Ok(SubspaceSet {
            models,
            kappa: opts.kappa,
            regularization: opts.regularization,
            elapsed: t0.elapsed(),
            step1_elapsed: marginals.elapsed,
        })
    }

    /// Step 3: the sparse weighted grid coreset + subspace geometry, via
    /// the free-variable FAQ. Fails when the FEQ output is empty.
    pub fn coreset(&self, subspaces: &SubspaceSet) -> Result<Coreset> {
        let t0 = crate::util::timer::now();
        let (grid, subs) = build_grid(self.db(), self.feq(), &self.tree, &subspaces.models)?;
        let elapsed = t0.elapsed();
        if grid.n() == 0 {
            anyhow::bail!("FEQ output is empty: nothing to cluster");
        }
        Ok(Coreset {
            grid,
            subspaces: subs,
            models: subspaces.models.clone(),
            elapsed,
            timings123: StepTimings {
                step1_marginals: subspaces.step1_elapsed,
                step2_subspaces: subspaces.elapsed,
                step3_grid: elapsed,
                step4_cluster: Duration::default(),
            },
        })
    }

    /// Sharded Step 3: the same coreset as [`RkPipeline::coreset`], built
    /// from `shards` value-hashed horizontal shards of the fact relation
    /// (the FEQ's first relation) running as independent grid-weight
    /// jobs on the process-wide worker pool and merged by exact weight
    /// addition ([`crate::coreset::build_grid_sharded`]).
    ///
    /// Grid weights are tuple counts in the ring ℤ, so the result is
    /// **bitwise identical** to the unsharded build for any shard count;
    /// `shards <= 1` delegates to [`RkPipeline::coreset`] outright. This
    /// takes Steps 1–3 — the half of the pipeline the pool never reached
    /// — off the serial path: wall-clock scales with cores until the
    /// merge and the largest shard dominate. Must not be called from
    /// inside a pool worker (the pool is not reentrant).
    pub fn coreset_sharded(&self, subspaces: &SubspaceSet, shards: usize) -> Result<Coreset> {
        if shards <= 1 {
            return self.coreset(subspaces);
        }
        let t0 = crate::util::timer::now();
        let (grid, subs) =
            build_grid_sharded(self.db(), self.feq(), &self.tree, &subspaces.models, shards)?;
        let elapsed = t0.elapsed();
        if grid.n() == 0 {
            anyhow::bail!("FEQ output is empty: nothing to cluster");
        }
        Ok(Coreset {
            grid,
            subspaces: subs,
            models: subspaces.models.clone(),
            elapsed,
            timings123: StepTimings {
                step1_marginals: subspaces.step1_elapsed,
                step2_subspaces: subspaces.elapsed,
                step3_grid: elapsed,
                step4_cluster: Duration::default(),
            },
        })
    }

    /// All four stages with the options an [`RkConfig`] implies — the
    /// staged body of the one-shot [`rkmeans`](crate::rkmeans::rkmeans)
    /// shim.
    pub fn run(&self, cfg: &RkConfig) -> Result<RkModel> {
        let marginals = self.marginals()?;
        let subspaces = self.subspaces(&marginals, &SubspaceOpts::from_config(cfg))?;
        let coreset = self.coreset(&subspaces)?;
        Ok(coreset.cluster(&ClusterOpts::from_config(cfg)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Attr, Relation, Schema, Value};
    use crate::rkmeans::rkmeans;
    use crate::util::testkit::{assert_bitwise_result, assert_close};
    use crate::util::SplitMix64;

    /// Small 2-relation star with clusterable structure (mirrors the
    /// one-shot rkmeans tests).
    fn setup(n_fact: usize, seed: u64) -> (Database, Feq) {
        let mut rng = SplitMix64::new(seed);
        let mut fact = Relation::new(
            "fact",
            Schema::new(vec![Attr::cat("item", 8), Attr::double("units")]),
        );
        for _ in 0..n_fact {
            let item = rng.below(8) as u32;
            let units =
                if item < 4 { rng.uniform(0.0, 1.0) } else { rng.uniform(100.0, 101.0) };
            fact.push_row(&[Value::Cat(item), Value::Double(units)]);
        }
        let mut items =
            Relation::new("items", Schema::new(vec![Attr::cat("item", 8), Attr::double("price")]));
        for i in 0..8u32 {
            items.push_row(&[Value::Cat(i), Value::Double(if i < 4 { 1.0 } else { 50.0 })]);
        }
        let mut db = Database::new();
        db.add(fact);
        db.add(items);
        let feq = Feq::with_features(&["fact", "items"], &["item", "units", "price"]);
        (db, feq)
    }

    #[test]
    fn staged_matches_one_shot_bitwise() {
        let (db, feq) = setup(250, 1);
        for cfg in [
            RkConfig::new(4),
            RkConfig::new(6).with_kappa(3),
            RkConfig::new(5).with_regularization(20.0),
        ] {
            let shim = rkmeans(&db, &feq, &cfg).unwrap();
            let staged = RkPipeline::plan(&db, &feq)
                .unwrap()
                .run(&cfg)
                .unwrap()
                .into_result();
            assert_bitwise_result(&shim, &staged, &format!("k={} κ={}", cfg.k, cfg.kappa));
        }
    }

    #[test]
    fn marginals_are_reusable_across_kappa() {
        let (db, feq) = setup(200, 2);
        let pipe = RkPipeline::plan(&db, &feq).unwrap();
        let marginals = pipe.marginals().unwrap();
        assert_close(marginals.output_size, 200.0, 1e-9);
        assert!(marginals.get("units").is_some());
        assert!(marginals.get("nope").is_none());

        let s2 = pipe.subspaces(&marginals, &SubspaceOpts::new(2)).unwrap();
        let s4 = pipe.subspaces(&marginals, &SubspaceOpts::new(4)).unwrap();
        assert_eq!(s2.n_subspaces(), 3);
        for (a, b) in s2.models.iter().zip(&s4.models) {
            assert!(a.n_gids() <= b.n_gids(), "subspace {}", a.name);
        }
        // Larger κ: (weakly) finer grid, (weakly) lower quantization.
        let c2 = pipe.coreset(&s2).unwrap();
        let c4 = pipe.coreset(&s4).unwrap();
        assert!(c2.n() <= c4.n());
        assert!(s4.quantization_cost() <= s2.quantization_cost() + 1e-9);
        assert_close(c2.mass(), c4.mass(), 1e-9);
    }

    #[test]
    fn sweep_matches_independent_runs() {
        let (db, feq) = setup(220, 3);
        let kappa = 5;
        let ks = [2usize, 3, 5];

        let pipe = RkPipeline::plan(&db, &feq).unwrap();
        let marginals = pipe.marginals().unwrap();
        let subspaces = pipe.subspaces(&marginals, &SubspaceOpts::new(kappa)).unwrap();
        let coreset = pipe.coreset(&subspaces).unwrap();
        let swept = coreset.sweep(&ks, &ClusterOpts::new(0));

        for (&k, model) in ks.iter().zip(&swept) {
            let solo = rkmeans(&db, &feq, &RkConfig::new(k).with_kappa(kappa)).unwrap();
            assert_bitwise_result(&solo, &model.clone().into_result(), &format!("k={k}"));
        }
    }

    #[test]
    fn cluster_resume_matches_cluster_warm_bitwise() {
        let (db, feq) = setup(240, 6);
        let pipe = RkPipeline::plan(&db, &feq).unwrap();
        let marginals = pipe.marginals().unwrap();
        let subspaces = pipe.subspaces(&marginals, &SubspaceOpts::new(4)).unwrap();
        let coreset = pipe.coreset(&subspaces).unwrap();
        let opts = ClusterOpts::new(3);

        // Cold: resume with no state is exactly cluster().
        let (m0, st0) = coreset.cluster_resume(&opts, None, None);
        let base = coreset.cluster(&opts);
        assert_bitwise_result(&base.into_result(), &m0.clone().into_result(), "cold");

        // Warm continue: carried state is bitwise-identical to the cold
        // warm start from the same centroids.
        let warm = coreset.cluster_warm(&opts, Some(&m0.centroids));
        let (resumed, st1) = coreset.cluster_resume(&opts, Some(&m0.centroids), Some(&st0));
        assert_bitwise_result(&warm.into_result(), &resumed.clone().into_result(), "resumed");
        assert_eq!(st1.n(), coreset.n());

        // A k mismatch drops the state (resume rides on the warm start):
        // identical to the fresh run at the new k, no panic.
        let opts4 = ClusterOpts::new(4);
        let (fresh4, _) = coreset.cluster_resume(&opts4, Some(&resumed.centroids), Some(&st1));
        let base4 = coreset.cluster(&opts4);
        assert_bitwise_result(&base4.into_result(), &fresh4.into_result(), "k-mismatch");
    }

    #[test]
    fn ladder_sweep_seeds_from_previous_k() {
        let (db, feq) = setup(220, 8);
        let pipe = RkPipeline::plan(&db, &feq).unwrap();
        let marginals = pipe.marginals().unwrap();
        let subspaces = pipe.subspaces(&marginals, &SubspaceOpts::new(5)).unwrap();
        let coreset = pipe.coreset(&subspaces).unwrap();
        let ks = [2usize, 4, 8];
        let opts = ClusterOpts::new(0);
        let ladder = coreset.sweep_with(&ks, &opts, SweepMode::Ladder);
        let fresh = coreset.sweep(&ks, &opts);
        assert_eq!(ladder.len(), ks.len());
        for (l, f) in ladder.iter().zip(&fresh) {
            assert_eq!(l.k(), f.k());
            assert!(l.objective_grid.is_finite() && l.objective_grid >= 0.0);
        }
        // The first rung has no predecessor: bitwise-identical to fresh
        // seeding (the waiver only applies from the second rung on).
        assert_eq!(ladder[0].objective_grid.to_bits(), fresh[0].objective_grid.to_bits());
        // Growing k from the previous rung's converged centroids plus a
        // D² fill can only improve the objective (superset of centroids,
        // then monotone Lloyd).
        for w in ladder.windows(2) {
            assert!(
                w[1].objective_grid <= w[0].objective_grid * (1.0 + 1e-6),
                "ladder objective rose: {} -> {}",
                w[0].objective_grid,
                w[1].objective_grid
            );
        }
    }

    #[test]
    fn sharded_coreset_is_bitwise_identical_and_clusters_identically() {
        let (db, feq) = setup(260, 9);
        let pipe = RkPipeline::plan(&db, &feq).unwrap();
        let marginals = pipe.marginals().unwrap();
        let subspaces = pipe.subspaces(&marginals, &SubspaceOpts::new(4)).unwrap();
        let serial = pipe.coreset(&subspaces).unwrap();
        for shards in [1usize, 2, 3, 8] {
            let sharded = pipe.coreset_sharded(&subspaces, shards).unwrap();
            assert_eq!(sharded.n(), serial.n(), "S={shards}");
            assert_eq!(sharded.grid.gids, serial.grid.gids, "S={shards}");
            for (i, (a, b)) in
                sharded.grid.weights.iter().zip(&serial.grid.weights).enumerate()
            {
                assert_eq!(a.to_bits(), b.to_bits(), "S={shards} cell {i}");
            }
            let a = sharded.cluster(&ClusterOpts::new(3)).into_result();
            let b = serial.cluster(&ClusterOpts::new(3)).into_result();
            assert_bitwise_result(&b, &a, &format!("S={shards}"));
        }
    }

    #[test]
    fn from_shards_merges_hand_built_shards() {
        use crate::faq::shard_databases;
        let (db, feq) = setup(230, 10);
        let pipe = RkPipeline::plan(&db, &feq).unwrap();
        let marginals = pipe.marginals().unwrap();
        let subspaces = pipe.subspaces(&marginals, &SubspaceOpts::new(3)).unwrap();
        let serial = pipe.coreset(&subspaces).unwrap();

        let shard_dbs = shard_databases(&db, &feq.relations[0], 3).unwrap();
        let parts: Vec<Coreset> = shard_dbs
            .iter()
            .map(|sdb| {
                let tree = Hypergraph::from_feq(sdb, &feq).join_tree().unwrap();
                let (grid, subs) = build_grid(sdb, &feq, &tree, &subspaces.models).unwrap();
                Coreset::from_parts(grid, subs, subspaces.models.clone())
            })
            .collect();
        let merged = Coreset::from_shards(parts).unwrap();
        assert_eq!(merged.grid.gids, serial.grid.gids);
        for (a, b) in merged.grid.weights.iter().zip(&serial.grid.weights) {
            assert_eq!(a.to_bits(), b.to_bits());
        }

        // Pairwise merge goes through the same path.
        let two = shard_databases(&db, &feq.relations[0], 2)
            .unwrap()
            .iter()
            .map(|sdb| {
                let tree = Hypergraph::from_feq(sdb, &feq).join_tree().unwrap();
                let (grid, subs) = build_grid(sdb, &feq, &tree, &subspaces.models).unwrap();
                Coreset::from_parts(grid, subs, subspaces.models.clone())
            })
            .collect::<Vec<_>>();
        let mut it = two.into_iter();
        let merged2 = it.next().unwrap().merge(it.next().unwrap()).unwrap();
        assert_eq!(merged2.grid.gids, serial.grid.gids);

        // Zero shards is an error, mismatched κ is an error.
        assert!(Coreset::from_shards(Vec::new()).is_err());
        let other_kappa = pipe.subspaces(&marginals, &SubspaceOpts::new(2)).unwrap();
        let a = pipe.coreset(&subspaces).unwrap();
        let b = pipe.coreset(&other_kappa).unwrap();
        assert!(a.merge(b).is_err());
    }

    #[test]
    fn empty_join_fails_at_the_coreset_stage() {
        let (mut db, feq) = setup(50, 4);
        *db.get_mut("items").unwrap() =
            Relation::new("items", Schema::new(vec![Attr::cat("item", 8), Attr::double("price")]));
        let pipe = RkPipeline::plan(&db, &feq).unwrap();
        let marginals = pipe.marginals().unwrap();
        let subspaces = pipe.subspaces(&marginals, &SubspaceOpts::new(2)).unwrap();
        assert!(pipe.coreset(&subspaces).is_err());
    }

    #[test]
    fn model_assigns_like_the_grid_centroids() {
        // Serving sanity at the pipeline level: every grid cell's raw
        // representative must be assigned to a centroid at least as close
        // as any other (argmin property over the factored distances).
        let (db, feq) = setup(180, 5);
        let pipe = RkPipeline::plan(&db, &feq).unwrap();
        let model = pipe.run(&RkConfig::new(3)).unwrap();
        let fact = db.get("fact").unwrap();
        let items = db.get("items").unwrap();
        for r in 0..8usize.min(fact.n_rows()) {
            let item = fact.value(r, 0);
            let units = fact.value(r, 1);
            let price = items.value(item.as_cat().unwrap() as usize, 1);
            let vals = vec![item, units, price];
            let (c, d) = model.assign_with_distance(&vals);
            for other in 0..model.k() {
                assert!(d <= model.distance2(&vals, other) + 1e-9, "row {r} vs centroid {other}");
            }
            assert_eq!(c, model.assign(&vals));
        }
    }
}
