//! End-to-end validation driver (see EXPERIMENTS.md): the full system on a
//! real-shaped workload.
//!
//! ```sh
//! RKMEANS_SCALE=0.1 cargo run --release --offline --example retailer_analysis
//! ```
//!
//! Mirrors the paper's headline experiment on the Retailer workload:
//! 1. generate a Retailer database (5 relations, Zipf fact table, FD
//!    chains);
//! 2. run Rk-means for several k, with both κ = k and κ < k;
//! 3. run the materialize-then-cluster baseline ("psql + mlpack");
//! 4. report the Table-2 style rows: compute-X time, baseline cluster
//!    time, Rk-means time, speedup and relative approximation, plus the
//!    memory footprints that make the baseline infeasible at scale.
//!
//! All layers compose here: the FAQ engine (steps 1+3), the optimal
//! subspace solvers (step 2), the factored Lloyd (step 4), and — when
//! `artifacts/` is present — the XLA/PJRT Step-4 path for comparison.

use rkmeans::bench_harness::paper::{end_to_end, PaperCfg};
use rkmeans::bench_harness::Table;
use rkmeans::faq::output_size;
use rkmeans::query::Hypergraph;
use rkmeans::synthetic::{Dataset, Scale};
use rkmeans::util::{human_bytes, human_count};

fn main() -> anyhow::Result<()> {
    let scale: f64 =
        std::env::var("RKMEANS_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.05);
    let mut cfg = PaperCfg::new(scale);
    cfg.ks = vec![5, 10, 20];

    let ds = Dataset::Retailer;
    println!("== Retailer analysis (scale {scale}) ==");
    let db = ds.generate(Scale::custom(scale), cfg.seed);
    let feq = ds.feq();
    let tree = Hypergraph::from_feq(&db, &feq).join_tree()?;
    let x_rows = output_size(&db, &tree)?;
    println!(
        "|D| = {} tuples ({}), |X| = {} rows × {} features",
        human_count(db.total_rows()),
        human_bytes(db.total_bytes()),
        human_count(x_rows as u64),
        feq.n_features()
    );

    // Table-2 style comparison.
    let mut t = Table::new(
        "Retailer end-to-end: Rk-means vs materialize+cluster",
        &["k", "κ", "Compute X", "Cluster (baseline)", "Rk-means", "Speedup", "Rel.Approx", "|G|"],
    );
    let mut configs: Vec<(usize, usize)> = cfg.ks.iter().map(|&k| (k, k)).collect();
    configs.push((20, 10));
    for (k, kappa) in configs {
        let e = end_to_end(&db, &feq, k, kappa, &cfg)?;
        t.row(vec![
            k.to_string(),
            kappa.to_string(),
            format!("{:.2}s", e.t_materialize),
            format!("{:.2}s", e.t_baseline_cluster),
            format!("{:.2}s", e.t_rkmeans),
            format!("{:.2}×", e.speedup),
            e.rel_approx.map(|a| format!("{a:.3}")).unwrap_or_else(|| "-".into()),
            human_count(e.grid_points as u64),
        ]);
        println!(
            "k={k} κ={kappa}: baseline holds {} dense; Rk-means grid {}",
            human_bytes(e.baseline_bytes),
            human_count(e.grid_points as u64),
        );
    }
    println!("{}", t.render());

    // k-sweep over one shared coreset: the staged pipeline pays Steps
    // 1–3 once for the whole Table-2-style sweep (each row is
    // bitwise-identical to an independent run at that k).
    {
        use rkmeans::rkmeans::{ClusterOpts, RkPipeline, SubspaceOpts};
        let t0 = std::time::Instant::now();
        let pipe = RkPipeline::plan(&db, &feq)?;
        let marginals = pipe.marginals()?;
        let subspaces = pipe.subspaces(&marginals, &SubspaceOpts::new(20))?;
        let coreset = pipe.coreset(&subspaces)?;
        let shared = t0.elapsed();
        let mut sweep_t = Table::new(
            "k-sweep over one shared coreset (steps 1–3 amortized)",
            &["k", "objective", "iters", "step4"],
        );
        for model in coreset.sweep(&cfg.ks, &ClusterOpts::new(0).with_seed(cfg.seed)) {
            sweep_t.row(vec![
                model.k().to_string(),
                format!("{:.4e}", model.objective_grid),
                model.iters.to_string(),
                format!("{:?}", model.timings.step4_cluster),
            ]);
        }
        println!(
            "steps 1–3 once for the whole sweep: {shared:?} (|G| = {} cells, κ = 20)",
            human_count(coreset.n() as u64)
        );
        println!("{}", sweep_t.render());
    }

    // Optional: the XLA/PJRT Step-4 path on the k=10 coreset.
    xla_step4(&db, &feq, &tree, &cfg)?;
    Ok(())
}

/// Compare the factored native Step 4 with the XLA/PJRT artifact path.
#[cfg(feature = "pjrt")]
fn xla_step4(
    db: &rkmeans::data::Database,
    feq: &rkmeans::query::Feq,
    tree: &rkmeans::query::JoinTree,
    cfg: &PaperCfg,
) -> anyhow::Result<()> {
    use rkmeans::cluster::LloydConfig;
    use rkmeans::coreset::{build_grid, grid_dense_embed, solve_subspaces};
    use rkmeans::faq::{full_join_counts, marginals};
    use rkmeans::join::EmbedSpec;
    use rkmeans::runtime::PjrtRuntime;

    let art_dir = PjrtRuntime::default_dir();
    if !PjrtRuntime::available(&art_dir) {
        println!("(artifacts/ missing — run `make artifacts` for the XLA step-4 comparison)");
        return Ok(());
    }
    let rt = PjrtRuntime::load(&art_dir)?;
    let k = 10;
    let jc = full_join_counts(db, tree)?;
    let margs = marginals(db, feq, tree, &jc)?;
    let models = solve_subspaces(feq, &margs, k)?;
    let (grid, subspaces) = build_grid(db, feq, tree, &models)?;
    let spec = EmbedSpec::from_feq(db, feq)?;
    let dense = grid_dense_embed(&grid, &models, &spec);
    let lcfg = LloydConfig { k, seed: cfg.seed, ..LloydConfig::new(k) };

    let t0 = std::time::Instant::now();
    let native = rkmeans::cluster::sparse_lloyd(&grid, &subspaces, &lcfg);
    let t_native = t0.elapsed();
    match rt.lloyd(&dense, &grid.weights, spec.dims, &lcfg) {
        Ok(xla) => {
            let t0 = std::time::Instant::now();
            let _ = rt.lloyd(&dense, &grid.weights, spec.dims, &lcfg)?; // warm
            let t_xla = t0.elapsed();
            println!(
                "step-4 engines on |G|={} D={}: factored-native {:?} (obj {:.4e}) vs \
                 XLA-dense {:?} (obj {:.4e})",
                grid.n(),
                spec.dims,
                t_native,
                native.objective,
                t_xla,
                xla.objective
            );
        }
        Err(e) => println!("XLA step-4 skipped: {e}"),
    }
    Ok(())
}

/// Without the `pjrt` feature there is no artifact path to compare.
#[cfg(not(feature = "pjrt"))]
fn xla_step4(
    _db: &rkmeans::data::Database,
    _feq: &rkmeans::query::Feq,
    _tree: &rkmeans::query::JoinTree,
    _cfg: &PaperCfg,
) -> anyhow::Result<()> {
    println!("(built without `pjrt` — skip the XLA step-4 comparison)");
    Ok(())
}
