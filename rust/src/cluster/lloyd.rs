//! Dense weighted Lloyd k-means over row-major points.
//!
//! This is (a) the materialize-then-cluster baseline — the role mlpack
//! plays in the paper's Table 2 — and (b) the host-side twin of the
//! XLA/PJRT hot path (`runtime::XlaLloyd`, behind the `pjrt` feature),
//! kept in lock-step by tests so the two engines are interchangeable.
//!
//! The iteration itself runs on the shared Step-4 engine
//! ([`crate::cluster::engine::dense`]): a tiled `‖x‖² − 2·x·c + ‖c‖²`
//! microkernel, Hamerly bounds that skip the inner k-loop for points whose
//! assignment provably cannot change, and deterministic chunk-parallel
//! accumulation. [`weighted_lloyd`] uses the production configuration;
//! [`weighted_lloyd_with`] exposes the engine options (naive serial
//! reference, thread count) plus pruning statistics.

use super::engine::dense::lloyd_dense;
use super::engine::{EngineOpts, PruneStats};

/// Configuration for Lloyd iterations.
#[derive(Clone, Debug)]
pub struct LloydConfig {
    pub k: usize,
    pub max_iters: usize,
    /// Stop when the relative objective improvement drops below this.
    pub tol: f64,
    pub seed: u64,
}

impl LloydConfig {
    /// Defaults matching the paper's experimental setup (k-means++ init,
    /// run to convergence with a practical iteration cap).
    pub fn new(k: usize) -> Self {
        LloydConfig { k, max_iters: 50, tol: 1e-6, seed: 0xC0FFEE }
    }
}

/// Result of a Lloyd run.
#[derive(Clone, Debug)]
pub struct LloydResult {
    /// Row-major `k × d` centroids.
    pub centroids: Vec<f64>,
    /// Cluster id per point.
    pub assign: Vec<u32>,
    /// Final weighted objective Σ w·d²(x, C).
    pub objective: f64,
    /// Iterations executed.
    pub iters: usize,
}

/// Weighted Lloyd on `n × d` row-major `points` with per-point `weights`
/// (bounds-pruned, chunk-parallel production engine).
pub fn weighted_lloyd(points: &[f64], weights: &[f64], d: usize, cfg: &LloydConfig) -> LloydResult {
    lloyd_dense(points, weights, d, cfg, &EngineOpts::default()).0
}

/// Weighted Lloyd with explicit engine options; also returns the pruning
/// and throughput statistics ([`PruneStats`]).
pub fn weighted_lloyd_with(
    points: &[f64],
    weights: &[f64],
    d: usize,
    cfg: &LloydConfig,
    opts: &EngineOpts,
) -> (LloydResult, PruneStats) {
    lloyd_dense(points, weights, d, cfg, opts)
}

/// Evaluate the weighted k-means objective of fixed centroids on a dense
/// point set (used for cross-engine comparisons and full-`X` evaluation).
pub fn objective(points: &[f64], weights: &[f64], d: usize, centroids: &[f64]) -> f64 {
    let n = points.len() / d;
    let k = centroids.len() / d;
    let mut obj = 0.0;
    for i in 0..n {
        let x = &points[i * d..(i + 1) * d];
        let mut best = f64::INFINITY;
        for c in 0..k {
            let cc = &centroids[c * d..(c + 1) * d];
            let mut s = 0.0;
            for (a, b) in x.iter().zip(cc) {
                let t = a - b;
                s += t * t;
            }
            if s < best {
                best = s;
            }
        }
        obj += weights[i] * best;
    }
    obj
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::{assert_close, for_cases};
    use crate::util::SplitMix64;

    fn blobs(rng: &mut SplitMix64, centers: &[(f64, f64)], per: usize) -> (Vec<f64>, Vec<f64>) {
        let mut pts = Vec::new();
        for &(cx, cy) in centers {
            for _ in 0..per {
                pts.push(cx + 0.05 * rng.normal());
                pts.push(cy + 0.05 * rng.normal());
            }
        }
        let w = vec![1.0; pts.len() / 2];
        (pts, w)
    }

    #[test]
    fn recovers_separated_blobs() {
        let mut rng = SplitMix64::new(11);
        let centers = [(0.0, 0.0), (10.0, 0.0), (0.0, 10.0)];
        let (pts, w) = blobs(&mut rng, &centers, 50);
        let res = weighted_lloyd(&pts, &w, 2, &LloydConfig::new(3));
        // Objective ≈ n · E[d²] = 150 · 2·0.05² = 0.75.
        assert!(res.objective < 2.0, "objective {}", res.objective);
        // Every true center has a nearby learned centroid.
        for &(cx, cy) in &centers {
            let near = (0..3).any(|c| {
                let dx = res.centroids[c * 2] - cx;
                let dy = res.centroids[c * 2 + 1] - cy;
                dx * dx + dy * dy < 0.5
            });
            assert!(near, "no centroid near ({cx},{cy})");
        }
    }

    #[test]
    fn objective_decreases_monotonically() {
        // Lloyd's invariant: each iteration cannot increase the objective.
        for_cases(15, |rng| {
            let n = 20 + rng.below(60) as usize;
            let d = 1 + rng.below(4) as usize;
            let pts: Vec<f64> = (0..n * d).map(|_| rng.uniform(-5.0, 5.0)).collect();
            let w: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 2.0)).collect();
            let k = 2 + rng.below(4) as usize;
            let mut last = f64::INFINITY;
            for iters in 1..=6 {
                let cfg = LloydConfig { k, max_iters: iters, tol: 0.0, seed: 5 };
                let r = weighted_lloyd(&pts, &w, d, &cfg);
                assert!(
                    r.objective <= last + 1e-9,
                    "objective rose from {last} to {} at iter {iters}",
                    r.objective
                );
                last = r.objective;
            }
        });
    }

    #[test]
    fn weights_pull_centroid() {
        // Two points, k=1: centroid is the weighted mean.
        let pts = vec![0.0, 0.0, 1.0, 0.0];
        let w = vec![3.0, 1.0];
        let r = weighted_lloyd(&pts, &w, 2, &LloydConfig::new(1));
        assert_close(r.centroids[0], 0.25, 1e-9);
    }

    #[test]
    fn zero_weight_points_are_free() {
        let pts = vec![0.0, 100.0];
        let w = vec![1.0, 0.0];
        let r = weighted_lloyd(&pts, &w, 1, &LloydConfig::new(1));
        assert_close(r.centroids[0], 0.0, 1e-9);
        assert_close(r.objective, 0.0, 1e-9);
    }

    #[test]
    fn k_ge_n_zero_objective() {
        let pts = vec![0.0, 1.0, 2.0, 3.0];
        let w = vec![1.0; 4];
        let r = weighted_lloyd(&pts, &w, 1, &LloydConfig::new(10));
        assert_close(r.objective, 0.0, 1e-12);
    }

    #[test]
    fn objective_function_matches_result() {
        let mut rng = SplitMix64::new(7);
        let (pts, w) = blobs(&mut rng, &[(0.0, 0.0), (5.0, 5.0)], 30);
        let r = weighted_lloyd(&pts, &w, 2, &LloydConfig::new(2));
        let ev = objective(&pts, &w, 2, &r.centroids);
        assert_close(ev, r.objective, 1e-9);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = SplitMix64::new(9);
        let (pts, w) = blobs(&mut rng, &[(0.0, 0.0), (3.0, 3.0)], 20);
        let a = weighted_lloyd(&pts, &w, 2, &LloydConfig::new(2));
        let b = weighted_lloyd(&pts, &w, 2, &LloydConfig::new(2));
        assert_eq!(a.assign, b.assign);
        assert_eq!(a.centroids, b.centroids);
    }

    #[test]
    fn engine_options_do_not_change_the_answer() {
        let mut rng = SplitMix64::new(12);
        let (pts, w) = blobs(&mut rng, &[(0.0, 0.0), (4.0, 0.0), (0.0, 4.0)], 40);
        let cfg = LloydConfig::new(3);
        let (a, sa) = weighted_lloyd_with(&pts, &w, 2, &cfg, &EngineOpts::naive_serial());
        let (b, sb) = weighted_lloyd_with(&pts, &w, 2, &cfg, &EngineOpts::pruned());
        assert_eq!(a.assign, b.assign);
        assert_eq!(a.centroids, b.centroids);
        assert_eq!(a.objective.to_bits(), b.objective.to_bits());
        // The pruned run must do no more distance work than the naive one.
        assert!(sb.dist_evals <= sa.dist_evals);
        assert_eq!(sa.dist_evals_skipped, 0);
    }
}
