//! Centroid-delta shipping between model versions.
//!
//! A [`ModelDelta`] is the wire difference between two [`RkModel`]
//! versions: the centroid rows and Step-2 subspace models that actually
//! changed (compared **bitwise**, `f64::to_bits`), plus the scalar fit
//! summary — keyed by the monotone `(from_version, to_version)` pair so
//! a replica can only splice it onto the exact base it was diffed
//! against. On the incremental planner's patch path Step-2 models are
//! frozen bitwise across versions, so a typical delta ships a handful
//! of centroid rows instead of the categorical subspace payloads (heavy
//! + light key lists ≈ whole domains) that dominate a full snapshot —
//! that asymmetry is the `serve_delta_bytes_ratio` the bench gate
//! tracks.
//!
//! The contract is exact reconstruction: for any models `a`, `b`,
//!
//! ```text
//! a.apply_delta(&ModelDelta::from_bytes(&a.diff(&b).to_bytes())?)?
//!     .to_bytes() == b.to_bytes()      // bitwise
//! ```
//!
//! which holds because the delta reuses the model's canonical JSON
//! writer ([`crate::util::json`], shortest-repr f64 round-trips
//! bit-exactly) and unchanged parts are cloned from the base — which the
//! diff proved bitwise-equal to the target. Stale deltas (base version ≠
//! `from_version`) are rejected with [`DeltaApplyError::VersionGap`]
//! instead of silently producing a franken-model;
//! `tests/property_delta.rs` pins both properties across random
//! incremental patch/rebuild sequences.
//!
//! Across the process boundary the same bytes flow unchanged: the rpc
//! replication plane ([`crate::serve::rpc`]) broadcasts each published
//! delta's wire buffer verbatim to subscribed replica processes, and a
//! replica that hits [`DeltaApplyError::VersionGap`] (a dropped or
//! missed delta) requests a full snapshot and byte-verifies it before
//! rejoining the stream.

use crate::cluster::sparse_lloyd::CentroidCoord;
use crate::coreset::{SubspaceModel, SubspaceSolver};
use crate::rkmeans::model::{
    arr_field, check_coord, coord_from_json_raw, coord_json, expect_format, num_field,
    subspace_from_json, subspace_json, u64_str_field, usize_field,
};
use crate::rkmeans::{ModelParseError, RkModel};
use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::fmt;

/// Version tag of the `ModelDelta` byte format. Bumped on any
/// incompatible layout change; [`ModelDelta::from_bytes`] refuses other
/// versions.
pub const MODEL_DELTA_FORMAT_VERSION: usize = 1;

/// A versioned wire delta between two models (see module docs).
#[derive(Clone, Debug)]
pub struct ModelDelta {
    /// Version of the base model this delta was diffed against; apply
    /// refuses any other base.
    pub from_version: u64,
    /// Version of the target model apply reconstructs.
    pub to_version: u64,
    /// Target cluster count (rows beyond the base's k must be shipped;
    /// a shrink truncates).
    pub k: usize,
    /// Target subspace count.
    pub m: usize,
    /// Target weighted k-means objective on the coreset.
    pub objective_grid: f64,
    /// Target coreset quantization error.
    pub quantization_cost: f64,
    /// Target non-zero grid cells `|G|`.
    pub grid_points: usize,
    /// Target total grid mass.
    pub grid_mass: f64,
    /// Target Step-4 iteration count.
    pub iters: usize,
    /// Changed Step-2 subspace models, by subspace index (empty on the
    /// planner's patch path, which freezes Step 2 bitwise).
    pub subspaces: Vec<(usize, SubspaceModel)>,
    /// Changed centroid rows, by centroid index.
    pub rows: Vec<(usize, Vec<CentroidCoord>)>,
}

/// Why a delta could not be spliced onto a base model.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DeltaApplyError {
    /// The delta was diffed against a different base version — applying
    /// it would silently mix two states. Fetch the missing deltas (or a
    /// snapshot) instead.
    VersionGap {
        /// Version of the base model apply was called on.
        base: u64,
        /// Base version the delta expects.
        from: u64,
        /// Target version the delta produces.
        to: u64,
    },
    /// The delta's payload does not cover / fit the target shape
    /// (missing extension rows, out-of-range indices, coordinate-kind
    /// mismatches).
    Shape(ModelParseError),
}

impl fmt::Display for DeltaApplyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DeltaApplyError::VersionGap { base, from, to } => write!(
                f,
                "rkmodel-delta: stale delta: base model is at version {base} but the delta \
                 patches {from} → {to}; ship the missing deltas or a full snapshot"
            ),
            DeltaApplyError::Shape(e) => write!(f, "rkmodel-delta: {e}"),
        }
    }
}

impl std::error::Error for DeltaApplyError {}

impl From<ModelParseError> for DeltaApplyError {
    fn from(e: ModelParseError) -> DeltaApplyError {
        DeltaApplyError::Shape(e)
    }
}

/// Bitwise f64 equality — the serialization round-trips bits, so this is
/// exactly "serializes to the same bytes".
fn f64_eq(a: f64, b: f64) -> bool {
    a.to_bits() == b.to_bits()
}

fn f64s_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| f64_eq(*x, *y))
}

fn coord_eq(a: &CentroidCoord, b: &CentroidCoord) -> bool {
    match (a, b) {
        (CentroidCoord::Continuous(x), CentroidCoord::Continuous(y)) => f64_eq(*x, *y),
        (CentroidCoord::Categorical(x), CentroidCoord::Categorical(y)) => f64s_eq(x, y),
        _ => false,
    }
}

fn row_eq(a: &[CentroidCoord], b: &[CentroidCoord]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| coord_eq(x, y))
}

/// Equality over the solver's **serialized** fields (derived lookup
/// structures are recomputed deterministically from them on both sides).
fn solver_eq(a: &SubspaceSolver, b: &SubspaceSolver) -> bool {
    match (a, b) {
        (SubspaceSolver::Continuous(x), SubspaceSolver::Continuous(y)) => {
            f64s_eq(&x.centers, &y.centers)
                && f64s_eq(&x.boundaries, &y.boundaries)
                && f64_eq(x.cost, y.cost)
        }
        (SubspaceSolver::Categorical(x), SubspaceSolver::Categorical(y)) => {
            x.heavy == y.heavy
                && f64s_eq(&x.heavy_w, &y.heavy_w)
                && x.light.len() == y.light.len()
                && x.light.iter().zip(&y.light).all(|(p, q)| p.0 == q.0 && f64_eq(p.1, q.1))
                && f64_eq(x.cost, y.cost)
        }
        _ => false,
    }
}

fn subspace_eq(a: &SubspaceModel, b: &SubspaceModel) -> bool {
    a.name == b.name
        && f64_eq(a.lambda, b.lambda)
        && f64_eq(a.cost, b.cost)
        && solver_eq(&a.solver, &b.solver)
}

impl RkModel {
    /// The wire delta turning `self` into `target`: every centroid row
    /// and subspace model that differs bitwise (plus rows/subspaces
    /// beyond `self`'s shape), keyed `self.version → target.version`.
    pub fn diff(&self, target: &RkModel) -> ModelDelta {
        let subspaces = target
            .models
            .iter()
            .enumerate()
            .filter(|(j, m)| !self.models.get(*j).is_some_and(|base| subspace_eq(base, m)))
            .map(|(j, m)| (j, m.clone()))
            .collect();
        let rows = target
            .centroids
            .iter()
            .enumerate()
            .filter(|(i, row)| !self.centroids.get(*i).is_some_and(|base| row_eq(base, row)))
            .map(|(i, row)| (i, row.clone()))
            .collect();
        ModelDelta {
            from_version: self.version,
            to_version: target.version,
            k: target.k(),
            m: target.m(),
            objective_grid: target.objective_grid,
            quantization_cost: target.quantization_cost,
            grid_points: target.grid_points,
            grid_mass: target.grid_mass,
            iters: target.iters,
            subspaces,
            rows,
        }
    }

    /// Splice a delta onto this base, producing the target model. Fails
    /// with [`DeltaApplyError::VersionGap`] when the delta was not
    /// diffed against exactly this version, and with
    /// [`DeltaApplyError::Shape`] when the payload leaves holes or
    /// mismatches the target shape. On success the result serializes
    /// bit-identically to the writer's target model (module docs).
    pub fn apply_delta(&self, delta: &ModelDelta) -> Result<RkModel, DeltaApplyError> {
        if delta.from_version != self.version {
            return Err(DeltaApplyError::VersionGap {
                base: self.version,
                from: delta.from_version,
                to: delta.to_version,
            });
        }

        let mut models: Vec<Option<SubspaceModel>> =
            self.models.iter().take(delta.m).cloned().map(Some).collect();
        models.resize(delta.m, None);
        for (j, m) in &delta.subspaces {
            if *j >= delta.m {
                return Err(ModelParseError::bad(
                    "subspaces",
                    format!("delta subspace index {j} ≥ m = {}", delta.m),
                )
                .into());
            }
            models[*j] = Some(m.clone());
        }
        let models = models
            .into_iter()
            .enumerate()
            .map(|(j, m)| {
                m.ok_or_else(|| {
                    DeltaApplyError::Shape(ModelParseError::missing(format!(
                        "subspaces[{j}] (base has no subspace there and the delta ships none)"
                    )))
                })
            })
            .collect::<Result<Vec<_>, _>>()?;

        let mut centroids: Vec<Option<Vec<CentroidCoord>>> =
            self.centroids.iter().take(delta.k).cloned().map(Some).collect();
        centroids.resize(delta.k, None);
        for (i, row) in &delta.rows {
            if *i >= delta.k {
                return Err(ModelParseError::bad(
                    "centroids",
                    format!("delta centroid index {i} ≥ k = {}", delta.k),
                )
                .into());
            }
            centroids[*i] = Some(row.clone());
        }
        let centroids = centroids
            .into_iter()
            .enumerate()
            .map(|(i, row)| {
                row.ok_or_else(|| {
                    DeltaApplyError::Shape(ModelParseError::missing(format!(
                        "centroids[{i}] (base has no row there and the delta ships none)"
                    )))
                })
            })
            .collect::<Result<Vec<_>, _>>()?;

        // Every row — spliced or carried over — must fit the (possibly
        // re-solved) subspace models: k × m kind/κ checks, cheap next to
        // a publish.
        for row in &centroids {
            if row.len() != models.len() {
                return Err(ModelParseError::bad(
                    "centroids",
                    format!(
                        "centroid has {} coordinates but the model has {} subspaces",
                        row.len(),
                        models.len()
                    ),
                )
                .into());
            }
            for (coord, m) in row.iter().zip(&models) {
                check_coord(coord, m)?;
            }
        }

        Ok(RkModel::assemble(
            models,
            centroids,
            delta.objective_grid,
            delta.quantization_cost,
            delta.grid_points,
            delta.grid_mass,
            delta.iters,
            Default::default(),
            Default::default(),
            delta.to_version,
        ))
    }
}

impl ModelDelta {
    /// Total parts shipped (changed subspaces + changed centroid rows).
    pub fn changes(&self) -> usize {
        self.subspaces.len() + self.rows.len()
    }

    /// Serialize to the versioned byte format (canonical JSON, UTF-8) —
    /// the same writer as [`RkModel::to_bytes`], so every f64
    /// round-trips bit-exactly.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut top: BTreeMap<String, Json> = BTreeMap::new();
        top.insert("format".to_string(), Json::Str("rkmodel-delta".to_string()));
        top.insert("format_version".to_string(), Json::count(MODEL_DELTA_FORMAT_VERSION));
        top.insert("from_version".to_string(), Json::Str(self.from_version.to_string()));
        top.insert("to_version".to_string(), Json::Str(self.to_version.to_string()));
        top.insert("k".to_string(), Json::count(self.k));
        top.insert("m".to_string(), Json::count(self.m));
        top.insert("objective_grid".to_string(), Json::Num(self.objective_grid));
        top.insert("quantization_cost".to_string(), Json::Num(self.quantization_cost));
        top.insert("grid_points".to_string(), Json::count(self.grid_points));
        top.insert("grid_mass".to_string(), Json::Num(self.grid_mass));
        top.insert("iters".to_string(), Json::count(self.iters));
        top.insert(
            "subspaces".to_string(),
            Json::Arr(
                self.subspaces
                    .iter()
                    .map(|(j, m)| {
                        let mut o: BTreeMap<String, Json> = BTreeMap::new();
                        o.insert("j".to_string(), Json::count(*j));
                        o.insert("model".to_string(), subspace_json(m));
                        Json::Obj(o)
                    })
                    .collect(),
            ),
        );
        top.insert(
            "centroids".to_string(),
            Json::Arr(
                self.rows
                    .iter()
                    .map(|(i, row)| {
                        let mut o: BTreeMap<String, Json> = BTreeMap::new();
                        o.insert("i".to_string(), Json::count(*i));
                        o.insert(
                            "coords".to_string(),
                            Json::Arr(row.iter().map(coord_json).collect()),
                        );
                        Json::Obj(o)
                    })
                    .collect(),
            ),
        );
        Json::Obj(top).to_string().into_bytes()
    }

    /// Restore a delta from [`ModelDelta::to_bytes`] output, with the
    /// same typed-error discipline as [`RkModel::from_bytes`].
    pub fn from_bytes(bytes: &[u8]) -> Result<ModelDelta, ModelParseError> {
        let text = std::str::from_utf8(bytes).map_err(|_| ModelParseError::Utf8)?;
        let doc = json::parse(text).map_err(|e| ModelParseError::Json(e.to_string()))?;
        expect_format(&doc, "rkmodel-delta")?;
        let fmt = usize_field(&doc, "format_version")?;
        if fmt != MODEL_DELTA_FORMAT_VERSION {
            return Err(ModelParseError::UnsupportedFormatVersion {
                found: fmt,
                supported: MODEL_DELTA_FORMAT_VERSION,
            });
        }
        let from_version = u64_str_field(&doc, "from_version")?;
        let to_version = u64_str_field(&doc, "to_version")?;
        let k = usize_field(&doc, "k")?;
        let m = usize_field(&doc, "m")?;
        let objective_grid = num_field(&doc, "objective_grid")?;
        let quantization_cost = num_field(&doc, "quantization_cost")?;
        let grid_points = usize_field(&doc, "grid_points")?;
        let grid_mass = num_field(&doc, "grid_mass")?;
        let iters = usize_field(&doc, "iters")?;

        let mut subspaces = Vec::new();
        for entry in arr_field(&doc, "subspaces")? {
            let j = usize_field(entry, "j")?;
            let model = entry.get("model").ok_or_else(|| ModelParseError::missing("model"))?;
            subspaces.push((j, subspace_from_json(model)?));
        }

        let mut rows = Vec::new();
        for entry in arr_field(&doc, "centroids")? {
            let i = usize_field(entry, "i")?;
            let coords = arr_field(entry, "coords")?
                .iter()
                .map(coord_from_json_raw)
                .collect::<Result<Vec<_>, _>>()?;
            rows.push((i, coords));
        }

        Ok(ModelDelta {
            from_version,
            to_version,
            k,
            m,
            objective_grid,
            quantization_cost,
            grid_points,
            grid_mass,
            iters,
            subspaces,
            rows,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rkmeans::{ClusterOpts, RkPipeline, SubspaceOpts};
    use crate::synthetic::{retailer, Scale};

    fn base_model() -> RkModel {
        let db = retailer::generate(Scale::tiny(), 42);
        let feq = retailer::feq();
        let pipe = RkPipeline::plan(&db, &feq).unwrap();
        let marginals = pipe.marginals().unwrap();
        let subspaces = pipe.subspaces(&marginals, &SubspaceOpts::new(4)).unwrap();
        let coreset = pipe.coreset(&subspaces).unwrap();
        coreset.cluster(&ClusterOpts::new(4)).with_version(3)
    }

    /// A target sharing most rows with the base: one centroid row moved,
    /// everything else (incl. Step-2 models) bitwise-identical.
    fn moved_row_target(base: &RkModel) -> RkModel {
        let mut next = base.clone().with_version(4);
        match &mut next.centroids[0][0] {
            CentroidCoord::Continuous(mu) => *mu += 1.5,
            CentroidCoord::Categorical(beta) => beta[0] += 0.25,
        }
        next.objective_grid += 0.125;
        next.iters += 1;
        next
    }

    #[test]
    fn diff_ships_only_changed_rows() {
        let base = base_model();
        let next = moved_row_target(&base);
        let delta = base.diff(&next);
        assert_eq!(delta.subspaces.len(), 0, "Step-2 models did not change");
        assert_eq!(delta.rows.len(), 1, "exactly one centroid row moved");
        assert_eq!(delta.rows[0].0, 0);
        assert_eq!((delta.from_version, delta.to_version), (3, 4));
        assert!(
            delta.to_bytes().len() * 2 < next.to_bytes().len(),
            "a one-row delta must be far smaller than the snapshot"
        );
    }

    #[test]
    fn apply_round_trips_bitwise() {
        let base = base_model();
        let next = moved_row_target(&base);
        let wire = base.diff(&next).to_bytes();
        let decoded = ModelDelta::from_bytes(&wire).unwrap();
        let applied = base.apply_delta(&decoded).unwrap();
        assert_eq!(applied.to_bytes(), next.to_bytes(), "delta splice must be bit-exact");
        // Self-delta: zero parts, still applies cleanly.
        let idem = next.apply_delta(&next.diff(&next)).unwrap();
        assert_eq!(idem.to_bytes(), next.to_bytes());
        assert_eq!(next.diff(&next).changes(), 0);
    }

    #[test]
    fn stale_delta_is_rejected() {
        let base = base_model();
        let next = moved_row_target(&base);
        let delta = base.diff(&next);
        let stranger = base.clone().with_version(99);
        match stranger.apply_delta(&delta) {
            Err(DeltaApplyError::VersionGap { base: b, from, to }) => {
                assert_eq!((b, from, to), (99, 3, 4));
            }
            other => panic!("expected VersionGap, got {other:?}"),
        }
    }

    #[test]
    fn delta_bytes_reject_version_and_garbage() {
        let base = base_model();
        let wire = base.diff(&moved_row_target(&base)).to_bytes();
        let text = String::from_utf8(wire).unwrap();
        let bumped = text.replace("\"format_version\":1", "\"format_version\":7");
        assert_ne!(text, bumped);
        let err = ModelDelta::from_bytes(bumped.as_bytes()).unwrap_err();
        assert!(err.to_string().contains("unsupported format version 7"), "got: {err}");
        // A model snapshot is not a delta document.
        assert!(matches!(
            ModelDelta::from_bytes(&base.to_bytes()),
            Err(ModelParseError::NotADocument { expected: "rkmodel-delta" })
        ));
    }

    #[test]
    fn oversize_count_in_delta_is_a_typed_error() {
        let base = base_model();
        let wire = base.diff(&moved_row_target(&base)).to_bytes();
        let text = String::from_utf8(wire).unwrap();
        // 2^53 + 1 collapses to 2^53 as an f64; the decoder must refuse
        // the ambiguous count rather than splice a truncated k.
        let broken = text.replace("\"k\":4", "\"k\":9007199254740993");
        assert_ne!(text, broken, "fixture must actually inflate k");
        let err = ModelDelta::from_bytes(broken.as_bytes()).unwrap_err();
        assert!(
            matches!(err, ModelParseError::BadField { ref field, .. } if field == "k"),
            "expected BadField(k), got {err:?}"
        );
    }
}
