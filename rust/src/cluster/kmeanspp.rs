//! Weighted k-means++ seeding (Arthur & Vassilvitskii [7]), generic over
//! the point geometry: both the dense Lloyd baseline and the factored
//! sparse Lloyd seed through this by supplying a `dist2(point, chosen)`
//! oracle.

use crate::util::SplitMix64;

/// Choose `k` seed *indices* among `n` weighted points by D² sampling.
///
/// `dist2(i, j)` must return the squared distance between points `i` and
/// `j`. The first seed is drawn proportionally to `weights`; each
/// subsequent seed proportionally to `w_i · min_c d²(i, c)`.
///
/// # Examples
///
/// ```
/// use rkmeans::cluster::kmeanspp_indices;
/// use rkmeans::util::SplitMix64;
///
/// let pts = [0.0_f64, 0.5, 10.0, 10.5, 20.0];
/// let w = [1.0; 5];
/// let d2 = |i: usize, j: usize| (pts[i] - pts[j]) * (pts[i] - pts[j]);
/// let seeds = kmeanspp_indices(5, &w, 3, &mut SplitMix64::new(7), d2);
/// assert_eq!(seeds.len(), 3);
/// // Deterministic for a fixed RNG seed.
/// let again = kmeanspp_indices(5, &w, 3, &mut SplitMix64::new(7), d2);
/// assert_eq!(seeds, again);
/// ```
pub fn kmeanspp_indices(
    n: usize,
    weights: &[f64],
    k: usize,
    rng: &mut SplitMix64,
    mut dist2: impl FnMut(usize, usize) -> f64,
) -> Vec<usize> {
    assert_eq!(weights.len(), n);
    assert!(n > 0, "cannot seed from zero points");
    let k = k.min(n);

    let total_w: f64 = weights.iter().sum();
    let first = rng.weighted_index(weights, total_w);
    let mut chosen = vec![first];

    let mut mind2: Vec<f64> = (0..n).map(|i| dist2(i, first)).collect();
    while chosen.len() < k {
        let scores: Vec<f64> = mind2.iter().zip(weights).map(|(&d, &w)| d * w).collect();
        let total: f64 = scores.iter().sum();
        let next = if total > 0.0 {
            rng.weighted_index(&scores, total)
        } else {
            // All remaining mass is on already-chosen points (duplicates):
            // fall back to weight sampling among unchosen indices.
            let mut cand: Vec<usize> = (0..n).filter(|i| !chosen.contains(i)).collect();
            if cand.is_empty() {
                break;
            }
            let cw: Vec<f64> = cand.iter().map(|&i| weights[i].max(1e-30)).collect();
            let cwt: f64 = cw.iter().sum();
            let pick = rng.weighted_index(&cw, cwt);
            cand.remove(pick)
        };
        chosen.push(next);
        for i in 0..n {
            let d = dist2(i, next);
            if d < mind2[i] {
                mind2[i] = d;
            }
        }
    }
    chosen
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::for_cases;

    fn euclid2(pts: &[(f64, f64)]) -> impl Fn(usize, usize) -> f64 + '_ {
        move |i, j| {
            let (dx, dy) = (pts[i].0 - pts[j].0, pts[i].1 - pts[j].1);
            dx * dx + dy * dy
        }
    }

    #[test]
    fn picks_k_distinct_indices() {
        let pts: Vec<(f64, f64)> = (0..20).map(|i| (i as f64, 0.0)).collect();
        let w = vec![1.0; 20];
        let mut rng = SplitMix64::new(1);
        let seeds = kmeanspp_indices(20, &w, 5, &mut rng, euclid2(&pts));
        assert_eq!(seeds.len(), 5);
        let mut sorted = seeds.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 5, "seeds must be distinct");
    }

    #[test]
    fn spreads_over_separated_clusters() {
        // 3 tight clusters; 3 seeds should land one in each almost surely.
        let mut pts = Vec::new();
        for c in 0..3 {
            for i in 0..10 {
                pts.push((c as f64 * 100.0 + (i as f64) * 0.01, 0.0));
            }
        }
        let w = vec![1.0; pts.len()];
        let mut hit_all = 0;
        for seed in 0..20u64 {
            let mut rng = SplitMix64::new(seed);
            let seeds = kmeanspp_indices(pts.len(), &w, 3, &mut rng, euclid2(&pts));
            let mut clusters: Vec<usize> = seeds.iter().map(|&i| i / 10).collect();
            clusters.sort_unstable();
            clusters.dedup();
            if clusters.len() == 3 {
                hit_all += 1;
            }
        }
        assert!(hit_all >= 18, "D² sampling should separate clusters ({hit_all}/20)");
    }

    #[test]
    fn zero_weight_points_never_first() {
        let pts = vec![(0.0, 0.0), (1.0, 0.0)];
        let w = vec![0.0, 1.0];
        for seed in 0..10 {
            let mut rng = SplitMix64::new(seed);
            let seeds = kmeanspp_indices(2, &w, 1, &mut rng, euclid2(&pts));
            assert_eq!(seeds[0], 1);
        }
    }

    #[test]
    fn duplicate_points_fall_back_gracefully() {
        // All points identical: D² mass is zero after the first seed.
        let pts = vec![(1.0, 1.0); 5];
        let w = vec![1.0; 5];
        let mut rng = SplitMix64::new(3);
        let seeds = kmeanspp_indices(5, &w, 3, &mut rng, euclid2(&pts));
        assert_eq!(seeds.len(), 3);
        let mut s = seeds.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn k_capped_at_n() {
        let pts = vec![(0.0, 0.0), (1.0, 0.0)];
        let w = vec![1.0, 1.0];
        let mut rng = SplitMix64::new(4);
        let seeds = kmeanspp_indices(2, &w, 10, &mut rng, euclid2(&pts));
        assert_eq!(seeds.len(), 2);
    }

    #[test]
    fn deterministic_for_seed() {
        for_cases(5, |rng| {
            let n = 5 + rng.below(20) as usize;
            let pts: Vec<(f64, f64)> =
                (0..n).map(|_| (rng.uniform(-5.0, 5.0), rng.uniform(-5.0, 5.0))).collect();
            let w: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 2.0)).collect();
            let s1 = kmeanspp_indices(n, &w, 3, &mut SplitMix64::new(99), euclid2(&pts));
            let s2 = kmeanspp_indices(n, &w, 3, &mut SplitMix64::new(99), euclid2(&pts));
            assert_eq!(s1, s2);
        });
    }
}
