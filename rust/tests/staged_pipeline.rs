//! Integration tests for the staged pipeline API: staged ≡ one-shot
//! exactness on the paper synthetics, the cyclic-FEQ rewrite through both
//! entry points (identical grids), `RkModel` serialization round-trips
//! under random tuples, assignment vs. the dense-centroid argmin, and
//! serving an exported model from a **fresh process** via the CLI.

use rkmeans::coreset::{centroids_dense, SubspaceSolver};
use rkmeans::data::{Attr, Database, Relation, Schema, Value};
use rkmeans::faq::{grid_weights, GidAssigner};
use rkmeans::join::{ensure_acyclic, materialize, EmbedSpec};
use rkmeans::query::{Feq, Hypergraph};
use rkmeans::rkmeans::{rkmeans, ClusterOpts, RkConfig, RkModel, RkPipeline, SubspaceOpts};
use rkmeans::synthetic::{Dataset, Scale};
use rkmeans::util::testkit::assert_bitwise_result;
use rkmeans::util::{FxHashMap, SplitMix64};

#[test]
fn staged_is_bitwise_identical_to_shim_on_paper_synthetics() {
    for ds in [Dataset::Retailer, Dataset::Favorita] {
        let db = ds.generate(Scale::tiny(), 31);
        let feq = ds.feq();
        for cfg in [RkConfig::new(5), RkConfig::new(8).with_kappa(4)] {
            let shim = rkmeans(&db, &feq, &cfg).unwrap();
            let pipe = RkPipeline::plan(&db, &feq).unwrap();
            let marginals = pipe.marginals().unwrap();
            let subspaces =
                pipe.subspaces(&marginals, &SubspaceOpts::from_config(&cfg)).unwrap();
            let coreset = pipe.coreset(&subspaces).unwrap();
            let staged = coreset.cluster(&ClusterOpts::from_config(&cfg)).into_result();
            assert_bitwise_result(&shim, &staged, ds.name());
        }
    }
}

/// A triangle query with payload features (cyclic: needs the rewrite).
fn cyclic_setup() -> (Database, Feq) {
    let mut rng = SplitMix64::new(41);
    let mk = |name: &str, a: &str, b: &str, rng: &mut SplitMix64| {
        let mut r = Relation::new(
            name,
            Schema::new(vec![
                Attr::cat(a, 5),
                Attr::cat(b, 5),
                Attr::double(&format!("p_{name}")),
            ]),
        );
        for _ in 0..40 {
            r.push_row(&[
                Value::Cat(rng.below(5) as u32),
                Value::Cat(rng.below(5) as u32),
                Value::Double(rng.below(8) as f64),
            ]);
        }
        r
    };
    let mut db = Database::new();
    db.add(mk("r", "a", "b", &mut rng));
    db.add(mk("s", "b", "c", &mut rng));
    db.add(mk("t", "c", "a", &mut rng));
    let feq = Feq::with_features(&["r", "s", "t"], &["p_r", "p_s", "p_t", "a", "b", "c"]);
    (db, feq)
}

#[test]
fn cyclic_feq_rewrite_identical_through_both_entry_points() {
    let (db, feq) = cyclic_setup();
    assert!(Hypergraph::from_feq(&db, &feq).join_tree().is_err(), "should be cyclic");
    let cfg = RkConfig::new(4);

    // One-shot shim and staged pipeline must agree bitwise.
    let shim = rkmeans(&db, &feq, &cfg).unwrap();
    let pipe = RkPipeline::plan(&db, &feq).unwrap();
    assert!(pipe.was_rewritten());
    let marginals = pipe.marginals().unwrap();
    let subspaces = pipe.subspaces(&marginals, &SubspaceOpts::from_config(&cfg)).unwrap();
    let coreset = pipe.coreset(&subspaces).unwrap();
    let staged = coreset.cluster(&ClusterOpts::from_config(&cfg)).into_result();
    assert_bitwise_result(&shim, &staged, "triangle");

    // And the staged coreset grid is exactly the grid the shim's models
    // induce over the acyclic rewrite: identical cells, identical weights.
    let (db2, feq2) = ensure_acyclic(&db, &feq).unwrap();
    let tree = Hypergraph::from_feq(&db2, &feq2).join_tree().unwrap();
    let mut assigners: FxHashMap<String, Box<dyn GidAssigner + '_>> = FxHashMap::default();
    for m in &shim.models {
        assigners.insert(m.name.clone(), Box::new(m));
    }
    let table = grid_weights(&db2, &feq2, &tree, &assigners).unwrap();
    let mut cells = table.cells;
    cells.sort_by(|x, y| x.0.cmp(&y.0));
    assert_eq!(cells.len(), coreset.n(), "grid cell count");
    let m = coreset.grid.m;
    for (i, (g, w)) in cells.iter().enumerate() {
        assert_eq!(&coreset.grid.gids[i * m..(i + 1) * m], &g[..], "cell {i}");
        assert_eq!(w.to_bits(), coreset.grid.weights[i].to_bits(), "cell {i} weight");
    }
}

#[test]
fn model_round_trip_preserves_assign_on_random_tuples() {
    let db = Dataset::Retailer.generate(Scale::tiny(), 7);
    let feq = Dataset::Retailer.feq();
    let pipe = RkPipeline::plan(&db, &feq).unwrap();
    let model = pipe.run(&RkConfig::new(6)).unwrap();
    let restored = RkModel::from_bytes(&model.to_bytes()).unwrap();
    assert_eq!(restored.k(), model.k());
    assert_eq!(restored.m(), model.m());

    // Random raw tuples in FEQ feature order, typed per subspace solver
    // (categorical keys deliberately include unseen ones).
    let mut rng = SplitMix64::new(99);
    for case in 0..200 {
        let vals: Vec<Value> = model
            .models
            .iter()
            .map(|m| match &m.solver {
                SubspaceSolver::Continuous(_) => {
                    Value::Double((rng.uniform(-5.0, 60.0) * 4.0).round() / 4.0)
                }
                SubspaceSolver::Categorical(_) => Value::Int(rng.below(64) as i64),
            })
            .collect();
        assert_eq!(model.assign(&vals), restored.assign(&vals), "case {case}");
        for c in 0..model.k() {
            assert_eq!(
                model.distance2(&vals, c).to_bits(),
                restored.distance2(&vals, c).to_bits(),
                "case {case} centroid {c}"
            );
        }
    }
}

#[test]
fn model_version_mismatch_fails_with_clear_error() {
    let db = Dataset::Retailer.generate(Scale::tiny(), 11);
    let feq = Dataset::Retailer.feq();
    let model = RkPipeline::plan(&db, &feq).unwrap().run(&RkConfig::new(3)).unwrap();
    let text = String::from_utf8(model.to_bytes()).unwrap();
    let bumped = text.replace("\"format_version\":1", "\"format_version\":2");
    assert_ne!(text, bumped);
    let msg = RkModel::from_bytes(bumped.as_bytes()).unwrap_err().to_string();
    assert!(msg.contains("unsupported format version 2"), "unclear error: {msg}");
}

#[test]
fn assign_matches_dense_centroid_argmin_on_held_out_tuples() {
    let db = Dataset::Favorita.generate(Scale::tiny(), 13);
    let feq = Dataset::Favorita.feq();
    let res = rkmeans(&db, &feq, &RkConfig::new(5)).unwrap();
    let model = RkModel::from_result(&res);

    let spec = EmbedSpec::from_feq(&db, &feq).unwrap();
    let dense = centroids_dense(&res.centroids, &res.models, &spec);
    let d = spec.dims;
    let k = res.centroids.len();

    // "Held-out" tuples: actual join-output rows (the model never saw
    // them, only the grid coreset).
    let tree = Hypergraph::from_feq(&db, &feq).join_tree().unwrap();
    let x = materialize(&db, &feq, &tree).unwrap();
    let mut buf = vec![0.0; d];
    assert!(!x.rows.is_empty());
    for row in x.rows.iter().take(100) {
        spec.embed_into(row, &mut buf);
        let mut dists = vec![0.0f64; k];
        for (c, dist) in dists.iter_mut().enumerate() {
            *dist = buf
                .iter()
                .zip(&dense[c * d..(c + 1) * d])
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
        }
        // The factored serving distance equals the dense one.
        for (c, &dd) in dists.iter().enumerate() {
            let fd = model.distance2(row, c);
            assert!(
                (fd - dd).abs() <= 1e-8 * (1.0 + dd.abs()),
                "factored {fd} vs dense {dd} (centroid {c})"
            );
        }
        // And assign is the argmin over the dense distances.
        let assigned = model.assign(row);
        let min = dists.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(
            dists[assigned] <= min + 1e-8 * (1.0 + min.abs()),
            "assigned {assigned} at {} but min is {min}",
            dists[assigned]
        );
    }
}

#[test]
fn exported_model_serves_from_a_fresh_process() {
    let db = Dataset::Retailer.generate(Scale::tiny(), 3);
    let feq = Dataset::Retailer.feq();
    let model = RkPipeline::plan(&db, &feq).unwrap().run(&RkConfig::new(4)).unwrap();

    let dir = std::env::temp_dir().join(format!("rkmodel_fresh_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.rkm");
    std::fs::write(&path, model.to_bytes()).unwrap();

    // A tuple in FEQ feature order, plus its expected in-process cluster.
    let mut parts: Vec<String> = Vec::new();
    let mut vals: Vec<Value> = Vec::new();
    for m in &model.models {
        match &m.solver {
            SubspaceSolver::Continuous(_) => {
                vals.push(Value::Double(1.25));
                parts.push("1.25".to_string());
            }
            SubspaceSolver::Categorical(_) => {
                vals.push(Value::Int(0));
                parts.push("0".to_string());
            }
        }
    }
    let expected = model.assign(&vals);

    // A fresh process restores the model from bytes and serves the tuple
    // without ever touching a Database.
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_rkmeans"))
        .args(["assign", "--model", path.to_str().unwrap(), "--values", &parts.join(",")])
        .output()
        .expect("spawn rkmeans assign");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains(&format!("cluster {expected} (")),
        "expected cluster {expected} in: {stdout}"
    );

    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_dir(&dir);
}
