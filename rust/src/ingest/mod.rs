//! Multi-producer sharded ingest: per-shard bounded queues, barrier-free
//! shard-local patching, and epoch-consistent grid publication.
//!
//! The streaming [`crate::coordinator`] ingests one ordered delta stream
//! and fans out per batch, so Steps 1–3 scale with the slowest global
//! barrier. This tier removes the barrier from the write path:
//!
//! * **P producers, S queues.** [`IngestProducer`] handles stamp every
//!   [`TupleDelta`] with an epoch number and route it like
//!   [`crate::faq::shard_databases`] partitions the build side: fact
//!   deltas go to the one shard [`crate::faq::shard_of`] hashes their
//!   values to, dimension deltas broadcast to every shard. Queues are
//!   *bounded* (`sync_channel`, [`IngestConfig::queue_capacity`]) — a
//!   producer that outruns a shard blocks on that shard alone, with the
//!   stall counted in `ingest.backpressure` (per-queue depth is the
//!   `ingest.queue_depth.<s>` gauge family).
//! * **Barrier-free shard application.** [`IngestHub::pump`] drains the
//!   queues and advances every shard as far as its own seals allow, as
//!   independent jobs on the shared
//!   [`ExecPool`](crate::util::exec::ExecPool): shard A can be several
//!   epochs ahead of shard B (the skew is the `ingest.watermark_lag`
//!   gauge). Within one (shard, epoch) buffer the deltas are put in a
//!   *canonical order* (inserts before deletes, then by relation, value
//!   bits, and weight bits) before [`DeltaFaq::apply`] — producer
//!   interleave can otherwise present a delete before the insert it
//!   cancels. The Step-3 FAQ lives in the ring ℤ, so per-cell sums are
//!   order-free and the reorder is invisible in the result.
//! * **Epoch-consistent publication.** An epoch `e` is applied at a
//!   shard only when all P producers have sealed `e` there (per-producer
//!   FIFO guarantees every delta of `e` precedes its seal), and `e` is
//!   *closed* — eligible for publication — only when every shard's
//!   watermark has reached it. Closing merges the retained per-shard
//!   epoch snapshots by exact ring-ℤ weight addition
//!   ([`crate::incremental::sharded`]'s merge) and diffs against the
//!   previously closed grid, yielding one [`EpochPatch`]: the merged
//!   [`GridTable`], the composed splice log that keeps a carried Step-4
//!   [`EngineState`](crate::cluster::EngineState) aligned, and the
//!   epoch's logical single-stream delta sequence. On integer-weighted
//!   databases every closed grid is **bitwise identical** to a serial
//!   single-stream [`DeltaFaq`] fed the same logical deltas — the
//!   determinism contract, pinned by `tests/property_ingest.rs`.
//!
//! The coordinator feeds closed epochs to
//! [`IncrementalEngine::apply_epoch`](crate::incremental::IncrementalEngine::apply_epoch);
//! when that path rebuilds (drift, churn, schedule, cost model), the hub
//! must be re-anchored with [`IngestHub::rebase`] — shard states are
//! re-initialized from the rebuilt boundary with the *new* Step-2 gid
//! maps, and locally-applied epochs beyond the boundary are replayed
//! from their retained buffers, so no enqueued delta is ever lost.
//!
//! Resident memory per shard is bounded by the same cold-key spilling
//! the planner uses ([`IngestConfig::spill_budget`] →
//! [`DeltaFaq::set_spill_budget`]): recency-cold separator-key message
//! tables spill to a per-shard disk segment and reload transparently on
//! touch.

use crate::data::{Database, Value};
use crate::faq::{shard_databases, shard_of, GridTable};
use crate::incremental::sharded::{diff_splices, merge_cell_lists, AssignerMap};
use crate::incremental::{DeltaFaq, EpochPatch, PatchStats, SpillStats, TupleDelta};
use crate::metrics::Metrics;
use crate::query::{Feq, JoinTree};
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::time::Instant;

/// Ingest-tier shape knobs.
#[derive(Clone, Debug)]
pub struct IngestConfig {
    /// Number of independent producer handles the hub hands out.
    pub producers: usize,
    /// Per-shard queue + delta-state count (`<= 1` = one shard).
    pub shards: usize,
    /// Bounded capacity of each per-shard queue (entries). Producers
    /// block on a full queue — backpressure, never unbounded growth.
    pub queue_capacity: usize,
    /// Cold-key spill budget per shard state (see
    /// [`DeltaFaq::set_spill_budget`]; 0 disables spilling).
    pub spill_budget: usize,
}

impl Default for IngestConfig {
    fn default() -> Self {
        IngestConfig { producers: 1, shards: 1, queue_capacity: 1024, spill_budget: 0 }
    }
}

/// One queue entry: an epoch-stamped delta, or a producer's seal marking
/// that it will send nothing more for that epoch on this shard.
#[derive(Clone, Debug)]
enum Entry {
    Delta { epoch: u64, delta: TupleDelta },
    Seal { producer: usize, epoch: u64 },
}

/// Per-shard ingest state: the live [`DeltaFaq`], buffered not-yet-sealed
/// epochs, and the retained snapshots/batches of applied-but-not-yet-
/// globally-closed epochs (what [`IngestHub::rebase`] replays).
#[derive(Debug)]
struct ShardState {
    delta: DeltaFaq,
    /// Highest epoch applied to `delta` (0 = none; epochs are 1-based).
    watermark: u64,
    /// Epoch → buffered deltas awaiting the epoch's seals.
    buf: BTreeMap<u64, Vec<TupleDelta>>,
    /// Epoch → per-producer seal flags.
    seals: BTreeMap<u64, Vec<bool>>,
    /// Epoch → grid cells right after that epoch was applied here.
    snaps: BTreeMap<u64, Vec<(Vec<u32>, f64)>>,
    /// Epoch → Step-3 stats of that epoch's apply here.
    stats: BTreeMap<u64, PatchStats>,
    /// Epoch → the canonical-order batch applied here (replay source).
    applied: BTreeMap<u64, Vec<TupleDelta>>,
}

/// The consumer side of the ingest tier (see module docs). Owned and
/// pumped by a single non-pool thread (the coordinator worker).
pub struct IngestHub {
    fact: String,
    feq: Feq,
    tree: JoinTree,
    producers: usize,
    spill_budget: usize,
    txs: Vec<SyncSender<Entry>>,
    rxs: Vec<Receiver<Entry>>,
    shards: Vec<ShardState>,
    feature_names: Vec<String>,
    /// Merged grid at the last *closed* epoch (diff base for the next).
    last_merged: Vec<(Vec<u32>, f64)>,
    /// Highest globally closed epoch.
    closed: u64,
    /// Epoch → first time any of its entries reached the hub (latency).
    first_seen: BTreeMap<u64, Instant>,
    metrics: Metrics,
}

impl IngestHub {
    /// Build the hub over `db`: partition the fact relation, init one
    /// [`DeltaFaq`] per shard as parallel pool jobs (largest shard
    /// first), and open the bounded per-shard queues.
    pub fn new<'m, F>(
        db: &Database,
        feq: &Feq,
        tree: &JoinTree,
        cfg: &IngestConfig,
        make_assigners: F,
        metrics: Metrics,
    ) -> Result<IngestHub>
    where
        F: Fn() -> AssignerMap<'m> + Sync,
    {
        ensure!(cfg.producers >= 1, "ingest needs at least one producer");
        let n_shards = cfg.shards.max(1);
        let fact = feq.relations.first().context("FEQ names no relations")?.clone();
        let shard_dbs = shard_databases(db, &fact, n_shards)?;
        let mut order: Vec<usize> = (0..shard_dbs.len()).collect();
        order.sort_by_key(|&s| {
            std::cmp::Reverse(shard_dbs[s].get(&fact).map_or(0, |r| r.n_rows()))
        });
        let mut works: Vec<(Database, Option<Result<DeltaFaq>>)> =
            shard_dbs.into_iter().map(|sdb| (sdb, None)).collect();
        let pool = crate::util::exec::shared_pool();
        pool.run_chunks_ordered(&mut works, 0, &order, |_, (sdb, out)| {
            let assigners = make_assigners();
            *out = Some(DeltaFaq::init(sdb, feq, tree, &assigners));
        });
        let mut deltas: Vec<DeltaFaq> = works
            .into_iter()
            .map(|(_, out)| out.expect("every shard init ran"))
            .collect::<Result<_>>()?;
        for d in &mut deltas {
            d.set_spill_budget(cfg.spill_budget);
        }
        let feature_names = deltas[0].grid_table().feature_names;
        let last_merged = {
            let lists: Vec<Vec<(Vec<u32>, f64)>> =
                deltas.iter().map(|d| d.grid_table().cells).collect();
            merge_cell_lists(&lists)
        };

        let cap = cfg.queue_capacity.max(1);
        let mut txs = Vec::with_capacity(n_shards);
        let mut rxs = Vec::with_capacity(n_shards);
        for s in 0..n_shards {
            // Bounded by construction (capacity >= 1): backpressure is
            // the contract, never unbounded queue growth.
            let (tx, rx) = sync_channel::<Entry>(cap);
            txs.push(tx);
            rxs.push(rx);
            metrics.gauge(&format!("ingest.queue_depth.{s}")).set(0);
        }
        let shards = deltas
            .into_iter()
            .map(|delta| ShardState {
                delta,
                watermark: 0,
                buf: BTreeMap::new(),
                seals: BTreeMap::new(),
                snaps: BTreeMap::new(),
                stats: BTreeMap::new(),
                applied: BTreeMap::new(),
            })
            .collect();
        Ok(IngestHub {
            fact,
            feq: feq.clone(),
            tree: tree.clone(),
            producers: cfg.producers,
            spill_budget: cfg.spill_budget,
            txs,
            rxs,
            shards,
            feature_names,
            last_merged,
            closed: 0,
            first_seen: BTreeMap::new(),
            metrics,
        })
    }

    /// A producer handle (`id < producers`). Handles are independent and
    /// movable across threads; each must seal every epoch it advances
    /// past, in order, on its own schedule.
    pub fn producer(&self, id: usize) -> IngestProducer {
        assert!(id < self.producers, "producer id {id} out of range (P = {})", self.producers);
        IngestProducer {
            id,
            fact: self.fact.clone(),
            txs: self.txs.clone(),
            metrics: self.metrics.clone(),
        }
    }

    /// Drain the queues, advance every shard as far as its seals allow
    /// (parallel, barrier-free), and close every epoch all shards have
    /// drained through. Returns the newly closed epochs in order. Call
    /// from a non-pool thread only. On error the shard states may be
    /// partially patched — [`IngestHub::rebase`] recovers (the failing
    /// epoch's buffer is put back and retried after the rebase).
    pub fn pump<'m, F>(&mut self, make_assigners: F) -> Result<Vec<EpochPatch>>
    where
        F: Fn() -> AssignerMap<'m> + Sync,
    {
        self.drain()?;
        self.advance(&make_assigners)?;
        self.close()
    }

    /// Move everything currently enqueued into the per-shard epoch
    /// buffers and seal tallies.
    fn drain(&mut self) -> Result<()> {
        for s in 0..self.rxs.len() {
            loop {
                // Empty and Disconnected both end the drain: disconnect
                // just means every producer handle has been dropped.
                let entry = match self.rxs[s].try_recv() {
                    Ok(e) => e,
                    Err(_) => break,
                };
                self.metrics.gauge(&format!("ingest.queue_depth.{s}")).add(-1);
                let producers = self.producers;
                let st = &mut self.shards[s];
                match entry {
                    Entry::Delta { epoch, delta } => {
                        ensure!(
                            epoch > st.watermark,
                            "shard {s}: delta for epoch {epoch} arrived after the epoch \
                             was applied (watermark {})",
                            st.watermark
                        );
                        self.first_seen.entry(epoch).or_insert_with(crate::util::timer::now);
                        st.buf.entry(epoch).or_default().push(delta);
                    }
                    Entry::Seal { producer, epoch } => {
                        ensure!(
                            epoch > st.watermark,
                            "shard {s}: seal of epoch {epoch} arrived after the epoch \
                             was applied (watermark {})",
                            st.watermark
                        );
                        ensure!(producer < producers, "unknown producer {producer}");
                        self.first_seen.entry(epoch).or_insert_with(crate::util::timer::now);
                        let sealed =
                            st.seals.entry(epoch).or_insert_with(|| vec![false; producers]);
                        ensure!(
                            !sealed[producer],
                            "shard {s}: duplicate seal of epoch {epoch} by producer {producer}"
                        );
                        sealed[producer] = true;
                    }
                }
            }
        }
        Ok(())
    }

    /// Advance every shard through its fully-sealed epochs as parallel
    /// pool jobs — no cross-shard barrier; each job stops exactly where
    /// its own seals run out.
    fn advance<'m, F>(&mut self, make_assigners: &F) -> Result<()>
    where
        F: Fn() -> AssignerMap<'m> + Sync,
    {
        let producers = self.producers;
        let mut works: Vec<(&mut ShardState, Option<Result<()>>)> =
            self.shards.iter_mut().map(|st| (st, None)).collect();
        let mut order: Vec<usize> = (0..works.len()).collect();
        order.sort_by_key(|&i| {
            std::cmp::Reverse(works[i].0.buf.iter().map(|(_, b)| b.len()).sum::<usize>())
        });
        let pool = crate::util::exec::shared_pool();
        pool.run_chunks_ordered(&mut works, 0, &order, |_, (st, out)| {
            *out = Some(advance_shard(st, producers, make_assigners));
        });
        for (_, out) in works {
            out.expect("every shard job ran")?;
        }
        Ok(())
    }

    /// Close every epoch all shards have drained through: merge the
    /// retained per-shard snapshots (exact ring-ℤ addition), diff
    /// against the previously closed grid, and reassemble the epoch's
    /// logical delta sequence.
    fn close(&mut self) -> Result<Vec<EpochPatch>> {
        let lo = self.shards.iter().map(|s| s.watermark).min().unwrap_or(0);
        let hi = self.shards.iter().map(|s| s.watermark).max().unwrap_or(0);
        self.metrics.gauge("ingest.watermark_lag").set((hi - lo) as i64);
        let mut out = Vec::new();
        while self.closed < lo {
            let e = self.closed + 1;
            let t0 = crate::util::timer::now();
            let lists: Vec<Vec<(Vec<u32>, f64)>> = self
                .shards
                .iter_mut()
                .map(|st| st.snaps.remove(&e).expect("snapshot exists for every applied epoch"))
                .collect();
            let merged = merge_cell_lists(&lists);
            let splices = diff_splices(&self.last_merged, &merged);
            self.metrics.histogram("ingest.merge_us").observe(t0.elapsed().as_micros() as u64);

            // Logical single-stream sequence: fact deltas live on exactly
            // one shard each; dimension deltas were broadcast, so take
            // them from shard 0 only.
            let mut deltas: Vec<TupleDelta> = Vec::new();
            let mut agg = PatchStats::default();
            for (s, st) in self.shards.iter_mut().enumerate() {
                let applied = st.applied.remove(&e).unwrap_or_default();
                if s == 0 {
                    deltas.extend(applied);
                } else {
                    deltas.extend(applied.into_iter().filter(|d| d.relation == self.fact));
                }
                let stats = st.stats.remove(&e).unwrap_or_default();
                agg.cells_touched += stats.cells_touched;
                agg.mass_delta_abs += stats.mass_delta_abs;
                agg.tombstone_ratio = agg.tombstone_ratio.max(stats.tombstone_ratio);
            }
            canonical_sort(&mut deltas);
            agg.deltas = deltas.len();
            agg.grid_cells = merged.len();

            if let Some(t) = self.first_seen.remove(&e) {
                self.metrics.histogram("ingest.epoch_us").observe(t.elapsed().as_micros() as u64);
            }
            self.metrics.counter("ingest.epochs_closed").inc();
            let table =
                GridTable { feature_names: self.feature_names.clone(), cells: merged.clone() };
            self.last_merged = merged;
            self.closed = e;
            out.push(EpochPatch { epoch: e, deltas, table, splices, stats: agg });
        }
        self.metrics.gauge("ingest.closed_epoch").set(self.closed as i64);
        Ok(out)
    }

    /// Re-anchor the hub after an engine rebuild at the last *closed*
    /// epoch: `db` must mirror exactly the closed epochs, and
    /// `make_assigners` must produce the rebuilt Step-2 gid maps. Shard
    /// states are re-initialized from the partitioned `db` and every
    /// locally-applied epoch beyond the boundary is replayed from its
    /// retained batch (regenerating its snapshot and stats under the new
    /// maps), so in-flight epochs survive the rebuild. Queues, buffers,
    /// seals, and watermarks are untouched.
    pub fn rebase<'m, F>(&mut self, db: &Database, make_assigners: F) -> Result<()>
    where
        F: Fn() -> AssignerMap<'m> + Sync,
    {
        let fact = self.fact.clone();
        let shard_dbs = shard_databases(db, &fact, self.shards.len())?;
        let spill_budget = self.spill_budget;
        let feq = &self.feq;
        let tree = &self.tree;
        let mut works: Vec<(Database, &mut ShardState, Option<Result<Vec<(Vec<u32>, f64)>>>)> =
            shard_dbs
                .into_iter()
                .zip(self.shards.iter_mut())
                .map(|(sdb, st)| (sdb, st, None))
                .collect();
        let mut order: Vec<usize> = (0..works.len()).collect();
        order.sort_by_key(|&i| {
            std::cmp::Reverse(works[i].0.get(&fact).map_or(0, |r| r.n_rows()))
        });
        let pool = crate::util::exec::shared_pool();
        pool.run_chunks_ordered(&mut works, 0, &order, |_, (sdb, st, out)| {
            *out = Some((|| -> Result<Vec<(Vec<u32>, f64)>> {
                let assigners = make_assigners();
                let mut delta = DeltaFaq::init(sdb, feq, tree, &assigners)?;
                delta.set_spill_budget(spill_budget);
                let base = delta.grid_table().cells;
                st.snaps.clear();
                st.stats.clear();
                for (e, batch) in &st.applied {
                    let stats = if batch.is_empty() {
                        PatchStats::default()
                    } else {
                        delta.apply(batch, &assigners)?
                    };
                    st.snaps.insert(*e, delta.grid_table().cells);
                    st.stats.insert(*e, stats);
                }
                st.delta = delta;
                Ok(base)
            })());
        });
        let mut bases = Vec::with_capacity(works.len());
        for (_, _, out) in works {
            bases.push(out.expect("every shard rebased")?);
        }
        self.last_merged = merge_cell_lists(&bases);
        Ok(())
    }

    /// Highest globally closed (published-or-publishable) epoch.
    pub fn closed_epoch(&self) -> u64 {
        self.closed
    }

    /// Per-shard watermarks (highest locally applied epoch each).
    pub fn watermarks(&self) -> Vec<u64> {
        self.shards.iter().map(|s| s.watermark).collect()
    }

    /// Number of shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Number of producer handles the hub was sized for.
    pub fn producer_count(&self) -> usize {
        self.producers
    }

    /// Merged grid at the last closed epoch.
    pub fn grid_table(&self) -> GridTable {
        GridTable { feature_names: self.feature_names.clone(), cells: self.last_merged.clone() }
    }

    /// Aggregate cold-key spill accounting across shard states.
    pub fn spill_stats(&self) -> SpillStats {
        self.shards
            .iter()
            .map(|s| s.delta.spill_stats())
            .fold(SpillStats::default(), |a, b| a.merged(b))
    }
}

/// A movable producer handle: epoch-stamps and routes deltas, seals
/// epochs. Cloned senders only — no shared mutable state, so any number
/// of threads can each own one.
pub struct IngestProducer {
    id: usize,
    fact: String,
    txs: Vec<SyncSender<Entry>>,
    metrics: Metrics,
}

impl IngestProducer {
    /// This producer's id.
    pub fn id(&self) -> usize {
        self.id
    }

    /// Enqueue one delta under `epoch` (1-based, non-decreasing per
    /// producer): fact deltas to their value-hash shard, dimension
    /// deltas to every shard. Blocks on a full shard queue.
    pub fn send(&self, epoch: u64, delta: TupleDelta) -> Result<()> {
        ensure!(epoch >= 1, "epochs are 1-based");
        if delta.relation == self.fact {
            let s = shard_of(&delta.values, self.txs.len());
            self.push(s, Entry::Delta { epoch, delta })?;
        } else {
            for s in 0..self.txs.len() {
                self.push(s, Entry::Delta { epoch, delta: delta.clone() })?;
            }
        }
        self.metrics.counter("ingest.enqueued").inc();
        Ok(())
    }

    /// Enqueue a batch under one epoch.
    pub fn send_batch(&self, epoch: u64, deltas: &[TupleDelta]) -> Result<()> {
        for d in deltas {
            self.send(epoch, d.clone())?;
        }
        Ok(())
    }

    /// Promise every shard that this producer sends nothing more for
    /// `epoch`. Every producer must seal every epoch, in order — an
    /// epoch closes only under all P seals at all S shards.
    pub fn seal(&self, epoch: u64) -> Result<()> {
        ensure!(epoch >= 1, "epochs are 1-based");
        for s in 0..self.txs.len() {
            self.push(s, Entry::Seal { producer: self.id, epoch })?;
        }
        Ok(())
    }

    fn push(&self, s: usize, entry: Entry) -> Result<()> {
        let entry = match self.txs[s].try_send(entry) {
            Ok(()) => {
                self.depth(s, 1);
                return Ok(());
            }
            Err(TrySendError::Full(entry)) => {
                self.metrics.counter("ingest.backpressure").inc();
                entry
            }
            Err(TrySendError::Disconnected(_)) => bail!("ingest shard {s} queue disconnected"),
        };
        self.txs[s]
            .send(entry)
            .map_err(|_| anyhow!("ingest shard {s} queue disconnected"))?;
        self.depth(s, 1);
        Ok(())
    }

    fn depth(&self, s: usize, d: i64) {
        self.metrics.gauge(&format!("ingest.queue_depth.{s}")).add(d);
    }
}

/// Apply every fully-sealed epoch buffered at one shard, in epoch order,
/// retaining the snapshot/stats/batch each needs at global close. On an
/// apply error the dequeued buffer and seals are put back so a rebased
/// retry sees the epoch again.
fn advance_shard<'m, F>(st: &mut ShardState, producers: usize, make_assigners: &F) -> Result<()>
where
    F: Fn() -> AssignerMap<'m> + Sync,
{
    loop {
        let next = st.watermark + 1;
        if !st.seals.get(&next).map_or(false, |v| v.iter().all(|&b| b)) {
            return Ok(());
        }
        st.seals.remove(&next);
        let mut batch = st.buf.remove(&next).unwrap_or_default();
        canonical_sort(&mut batch);
        let stats = if batch.is_empty() {
            PatchStats::default()
        } else {
            let assigners = make_assigners();
            match st.delta.apply(&batch, &assigners) {
                Ok(stats) => stats,
                Err(e) => {
                    st.buf.insert(next, batch);
                    st.seals.insert(next, vec![true; producers]);
                    return Err(e);
                }
            }
        };
        st.snaps.insert(next, st.delta.grid_table().cells);
        st.stats.insert(next, stats);
        st.applied.insert(next, batch);
        st.watermark = next;
    }
}

/// Canonical intra-epoch delta order: inserts before deletes (producer
/// interleave can present a delete ahead of the same-epoch insert it
/// cancels), then by relation, value bits (`-0.0` normalized to `0.0`),
/// and weight bits. Ring-ℤ per-cell sums are order-free, so the reorder
/// never changes the resulting grid — it only restores stream validity
/// and gives every shard a deterministic application order.
pub(crate) fn canonical_sort(deltas: &mut [TupleDelta]) {
    deltas.sort_by(|a, b| {
        a.is_delete()
            .cmp(&b.is_delete())
            .then_with(|| a.relation.cmp(&b.relation))
            .then_with(|| value_sort_key(&a.values).cmp(&value_sort_key(&b.values)))
            .then_with(|| a.weight.to_bits().cmp(&b.weight.to_bits()))
    });
}

fn value_sort_key(values: &[Value]) -> Vec<(u8, u64)> {
    values
        .iter()
        .map(|v| match v {
            Value::Int(x) => (0u8, *x as u64),
            Value::Double(x) => {
                let x = if *x == 0.0 { 0.0 } else { *x };
                (1u8, x.to_bits())
            }
            Value::Cat(c) => (2u8, *c as u64),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Attr, Relation, Schema};
    use crate::faq::GidAssigner;
    use crate::incremental::apply_to_db;
    use crate::query::Hypergraph;
    use crate::util::{FxHashMap, SplitMix64};

    #[derive(Clone, Copy)]
    struct ModAssigner {
        n: u32,
        claimed: usize,
    }
    impl GidAssigner for ModAssigner {
        fn gid(&self, v: Value) -> u32 {
            let k = match v {
                Value::Double(x) => (x * 2.0) as i64 as u64,
                other => other.key_u64(),
            };
            (k % self.n as u64) as u32
        }
        fn n_gids(&self) -> usize {
            self.claimed
        }
    }

    fn assigners(n: u32, claimed: usize) -> AssignerMap<'static> {
        let mut m: AssignerMap<'static> = FxHashMap::default();
        for a in ["a", "b", "c"] {
            m.insert(a.to_string(), Box::new(ModAssigner { n, claimed }));
        }
        m
    }

    /// fact(a, b) ⋈ dim(b, c), as in the sharded delta tests.
    fn setup(n_fact: usize, seed: u64) -> (Database, Feq, JoinTree) {
        let mut rng = SplitMix64::new(seed);
        let mut fact =
            Relation::new("fact", Schema::new(vec![Attr::cat("a", 8), Attr::cat("b", 8)]));
        for _ in 0..n_fact {
            fact.push_row(&[Value::Cat(rng.below(8) as u32), Value::Cat(rng.below(4) as u32)]);
        }
        let mut dim = Relation::new("dim", Schema::new(vec![Attr::cat("b", 8), Attr::cat("c", 8)]));
        for b in 0..4u32 {
            dim.push_row(&[Value::Cat(b), Value::Cat(b % 3)]);
        }
        let mut db = Database::new();
        db.add(fact);
        db.add(dim);
        let feq = Feq::with_features(&["fact", "dim"], &["a", "b", "c"]);
        let tree = Hypergraph::from_feq(&db, &feq).join_tree().unwrap();
        (db, feq, tree)
    }

    fn cells_bits(gt: &GridTable) -> Vec<(Vec<u32>, u64)> {
        gt.cells.iter().map(|(g, w)| (g.clone(), w.to_bits())).collect()
    }

    /// Insert-heavy batch with distinct-row deletes (no double deletes).
    fn random_batch(rng: &mut SplitMix64, db: &Database, n: usize) -> Vec<TupleDelta> {
        let mut out = Vec::new();
        let mut used: Vec<usize> = Vec::new();
        for _ in 0..n {
            if rng.below(5) < 2 {
                let fact = db.get("fact").unwrap();
                let live: Vec<usize> = (0..fact.n_rows())
                    .filter(|&r| fact.weight(r) > 0.0 && !used.contains(&r))
                    .collect();
                if let Some(&r) = live.get(rng.below(live.len().max(1) as u64) as usize) {
                    used.push(r);
                    out.push(TupleDelta::delete("fact", fact.row(r)));
                    continue;
                }
            }
            out.push(TupleDelta::insert(
                "fact",
                vec![Value::Cat(rng.below(8) as u32), Value::Cat(rng.below(4) as u32)],
            ));
        }
        out
    }

    #[test]
    fn epoch_close_matches_serial_single_stream_bitwise() {
        // Two producers interleaving, three shards: every closed epoch's
        // merged grid must be bitwise identical to a serial single-stream
        // DeltaFaq fed the same logical deltas in trace order.
        let (mut db, feq, tree) = setup(140, 1);
        let mut serial = DeltaFaq::init(&db, &feq, &tree, &assigners(3, 3)).unwrap();
        let cfg =
            IngestConfig { producers: 2, shards: 3, queue_capacity: 256, spill_budget: 0 };
        let metrics = Metrics::new();
        let mut hub =
            IngestHub::new(&db, &feq, &tree, &cfg, || assigners(3, 3), metrics.clone()).unwrap();
        assert_eq!(cells_bits(&hub.grid_table()), cells_bits(&serial.grid_table()));
        let p0 = hub.producer(0);
        let p1 = hub.producer(1);
        let mut rng = SplitMix64::new(5);
        for epoch in 1..=4u64 {
            let mut batch = random_batch(&mut rng, &db, 12);
            if epoch == 2 {
                // Dimension churn broadcasts to every shard.
                batch.push(TupleDelta::insert("dim", vec![Value::Cat(1), Value::Cat(7)]));
            }
            apply_to_db(&mut db, &batch).unwrap();
            // Interleave: producer 1 takes the odd positions, and sends
            // its share in reverse to stress the canonical reorder.
            for d in batch.iter().step_by(2) {
                p0.send(epoch, d.clone()).unwrap();
            }
            let odds: Vec<&TupleDelta> = batch.iter().skip(1).step_by(2).collect();
            for d in odds.into_iter().rev() {
                p1.send(epoch, d.clone()).unwrap();
            }
            p0.seal(epoch).unwrap();
            p1.seal(epoch).unwrap();
            let patches = hub.pump(|| assigners(3, 3)).unwrap();
            assert_eq!(patches.len(), 1, "epoch {epoch}");
            let patch = &patches[0];
            assert_eq!(patch.epoch, epoch);
            assert_eq!(patch.deltas.len(), batch.len());
            serial.apply(&batch, &assigners(3, 3)).unwrap();
            assert_eq!(
                cells_bits(&patch.table),
                cells_bits(&serial.grid_table()),
                "epoch {epoch}"
            );
            assert_eq!(patch.stats.grid_cells, serial.n_cells());
        }
        assert_eq!(hub.closed_epoch(), 4);
        assert_eq!(metrics.counter("ingest.epochs_closed").get(), 4);
        assert_eq!(metrics.histogram("ingest.epoch_us").count(), 4);
        // All queues fully drained.
        for s in 0..3 {
            assert_eq!(metrics.gauge(&format!("ingest.queue_depth.{s}")).get(), 0);
        }
    }

    #[test]
    fn publication_waits_for_every_seal() {
        // Epoch-consistency pin: with one producer's seal missing, no
        // version may publish — however many deltas are already in.
        let (mut db, feq, tree) = setup(80, 2);
        let cfg = IngestConfig { producers: 2, shards: 2, ..IngestConfig::default() };
        let mut hub =
            IngestHub::new(&db, &feq, &tree, &cfg, || assigners(3, 3), Metrics::new()).unwrap();
        let p0 = hub.producer(0);
        let p1 = hub.producer(1);
        let mut rng = SplitMix64::new(7);
        let batch = random_batch(&mut rng, &db, 10);
        apply_to_db(&mut db, &batch).unwrap();
        for (i, d) in batch.iter().enumerate() {
            if i % 2 == 0 {
                p0.send(1, d.clone()).unwrap();
            } else {
                p1.send(1, d.clone()).unwrap();
            }
        }
        p0.seal(1).unwrap();
        assert!(hub.pump(|| assigners(3, 3)).unwrap().is_empty());
        assert_eq!(hub.closed_epoch(), 0);
        assert_eq!(hub.watermarks(), vec![0, 0]);

        // The missing seal lands: the epoch closes with *all* deltas.
        p1.seal(1).unwrap();
        let patches = hub.pump(|| assigners(3, 3)).unwrap();
        assert_eq!(patches.len(), 1);
        assert_eq!(patches[0].deltas.len(), batch.len());
        // Reference: a fresh delta state over the post-batch database —
        // the closed grid must match it bitwise.
        let serial = DeltaFaq::init(&db, &feq, &tree, &assigners(3, 3)).unwrap();
        assert_eq!(cells_bits(&patches[0].table), cells_bits(&serial.grid_table()));
    }

    #[test]
    fn delete_before_insert_interleave_is_canonicalized() {
        // Producer 0's delete of a tuple arrives ahead of producer 1's
        // insert of that same new tuple within one epoch: canonical order
        // applies the insert first, so per-shard multiplicity never goes
        // negative and the epoch still closes bitwise-equal to serial.
        let (mut db, feq, tree) = setup(60, 3);
        let mut serial = DeltaFaq::init(&db, &feq, &tree, &assigners(3, 3)).unwrap();
        let cfg = IngestConfig { producers: 2, shards: 2, ..IngestConfig::default() };
        let mut hub =
            IngestHub::new(&db, &feq, &tree, &cfg, || assigners(3, 3), Metrics::new()).unwrap();
        let p0 = hub.producer(0);
        let p1 = hub.producer(1);
        let tuple = vec![Value::Cat(7), Value::Cat(3)];
        let trace = vec![
            TupleDelta::insert("fact", tuple.clone()),
            TupleDelta::delete("fact", tuple.clone()),
        ];
        apply_to_db(&mut db, &trace).unwrap();
        // Delete enqueued before the insert it cancels.
        p0.send(1, trace[1].clone()).unwrap();
        p1.send(1, trace[0].clone()).unwrap();
        p0.seal(1).unwrap();
        p1.seal(1).unwrap();
        let patches = hub.pump(|| assigners(3, 3)).unwrap();
        assert_eq!(patches.len(), 1);
        assert!(!patches[0].deltas[0].is_delete(), "canonical order puts inserts first");
        serial.apply(&trace, &assigners(3, 3)).unwrap();
        assert_eq!(cells_bits(&patches[0].table), cells_bits(&serial.grid_table()));
    }

    #[test]
    fn watermark_skew_and_rebase_replay_in_flight_epochs() {
        // Barrier-free pin: a shard whose seals all arrived advances past
        // the global close; a rebase at the closed boundary replays its
        // in-flight epoch from the retained buffer, and the epoch closes
        // bitwise-equal once the laggard catches up.
        let (mut db, feq, tree) = setup(100, 4);
        let mut serial = DeltaFaq::init(&db, &feq, &tree, &assigners(3, 3)).unwrap();
        let cfg = IngestConfig { producers: 1, shards: 2, ..IngestConfig::default() };
        let metrics = Metrics::new();
        let mut hub =
            IngestHub::new(&db, &feq, &tree, &cfg, || assigners(3, 3), metrics.clone()).unwrap();
        let p0 = hub.producer(0);

        // Epoch 1 closes normally.
        let mut rng = SplitMix64::new(9);
        let b1 = random_batch(&mut rng, &db, 8);
        apply_to_db(&mut db, &b1).unwrap();
        p0.send_batch(1, &b1).unwrap();
        p0.seal(1).unwrap();
        serial.apply(&b1, &assigners(3, 3)).unwrap();
        let patches = hub.pump(|| assigners(3, 3)).unwrap();
        assert_eq!(patches.len(), 1);
        let db_at_close = db.clone();

        // Epoch 2: a fact delta routed to one shard, whose seal reaches
        // only that shard (injected below the producer API).
        let b2: Vec<TupleDelta> = (0..4)
            .map(|i| {
                TupleDelta::insert("fact", vec![Value::Cat(i as u32 % 8), Value::Cat(1)])
            })
            .collect();
        for d in &b2 {
            let s = shard_of(&d.values, 2);
            hub.txs[s].send(Entry::Delta { epoch: 2, delta: d.clone() }).unwrap();
        }
        hub.txs[0].send(Entry::Seal { producer: 0, epoch: 2 }).unwrap();
        assert!(hub.pump(|| assigners(3, 3)).unwrap().is_empty());
        assert_eq!(hub.watermarks(), vec![2, 1]);
        assert_eq!(hub.closed_epoch(), 1);
        assert_eq!(metrics.gauge("ingest.watermark_lag").get(), 1);

        // A rebuild at the closed boundary: rebase from the epoch-1 db
        // with the same maps — the in-flight epoch 2 must be replayed.
        hub.rebase(&db_at_close, || assigners(3, 3)).unwrap();
        assert_eq!(hub.watermarks(), vec![2, 1]);
        assert_eq!(cells_bits(&hub.grid_table()), cells_bits(&serial.grid_table()));

        // The laggard's seal lands; epoch 2 closes bitwise-equal.
        hub.txs[1].send(Entry::Seal { producer: 0, epoch: 2 }).unwrap();
        apply_to_db(&mut db, &b2).unwrap();
        serial.apply(&b2, &assigners(3, 3)).unwrap();
        let patches = hub.pump(|| assigners(3, 3)).unwrap();
        assert_eq!(patches.len(), 1);
        assert_eq!(patches[0].epoch, 2);
        assert_eq!(cells_bits(&patches[0].table), cells_bits(&serial.grid_table()));
        assert_eq!(metrics.gauge("ingest.watermark_lag").get(), 0);
    }

    #[test]
    fn spilled_hub_matches_unspilled_bitwise() {
        // The per-shard spill budget is a residency knob only: a hub
        // spilling all but one message table per shard publishes the
        // same bits as an unspilled twin.
        let (mut db, feq, tree) = setup(120, 5);
        let plain_cfg = IngestConfig { producers: 2, shards: 2, ..IngestConfig::default() };
        let spill_cfg = IngestConfig { spill_budget: 1, ..plain_cfg.clone() };
        let mut plain =
            IngestHub::new(&db, &feq, &tree, &plain_cfg, || assigners(3, 3), Metrics::new())
                .unwrap();
        let mut spilly =
            IngestHub::new(&db, &feq, &tree, &spill_cfg, || assigners(3, 3), Metrics::new())
                .unwrap();
        let mut rng = SplitMix64::new(11);
        for epoch in 1..=3u64 {
            let batch = random_batch(&mut rng, &db, 10);
            apply_to_db(&mut db, &batch).unwrap();
            for hub in [&mut plain, &mut spilly] {
                let p0 = hub.producer(0);
                let p1 = hub.producer(1);
                for (i, d) in batch.iter().enumerate() {
                    if i % 2 == 0 {
                        p0.send(epoch, d.clone()).unwrap();
                    } else {
                        p1.send(epoch, d.clone()).unwrap();
                    }
                }
                p0.seal(epoch).unwrap();
                p1.seal(epoch).unwrap();
            }
            let a = plain.pump(|| assigners(3, 3)).unwrap();
            let b = spilly.pump(|| assigners(3, 3)).unwrap();
            assert_eq!(a.len(), 1);
            assert_eq!(b.len(), 1);
            assert_eq!(cells_bits(&a[0].table), cells_bits(&b[0].table), "epoch {epoch}");
        }
        assert!(spilly.spill_stats().spilled > 0, "budget 1 must force spills");
        assert!(spilly.spill_stats().reloaded > 0, "patching cold keys must reload");
        assert_eq!(plain.spill_stats(), SpillStats::default());
    }

    #[test]
    fn protocol_violations_are_rejected() {
        let (db, feq, tree) = setup(40, 6);
        let cfg = IngestConfig { producers: 1, shards: 1, ..IngestConfig::default() };
        let mut hub =
            IngestHub::new(&db, &feq, &tree, &cfg, || assigners(3, 3), Metrics::new()).unwrap();
        let p0 = hub.producer(0);
        assert!(p0.send(0, TupleDelta::insert("fact", vec![])).is_err(), "epoch 0 invalid");
        assert!(p0.seal(0).is_err());

        // Close epoch 1, then send a late delta for it: rejected.
        p0.seal(1).unwrap();
        assert_eq!(hub.pump(|| assigners(3, 3)).unwrap().len(), 1);
        p0.send(1, TupleDelta::insert("fact", vec![Value::Cat(0), Value::Cat(0)])).unwrap();
        let err = hub.pump(|| assigners(3, 3)).unwrap_err();
        assert!(err.to_string().contains("watermark"), "got: {err}");
    }
}
