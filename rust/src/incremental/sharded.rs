//! Shard-parallel Step-3 delta maintenance: per-shard [`DeltaFaq`]
//! instances over the same value-hashed fact partition the build side
//! uses ([`crate::faq::shard`]), patched in parallel and merged at the
//! root.
//!
//! Sharding any single relation of a join partitions the join output, so
//! S independent delta states over the fact shards maintain S grids whose
//! per-cell sum is the full grid. Because the Step-3 FAQ lives in the
//! ring ℤ, the merge is exact weight addition — on integer-weighted
//! databases the merged snapshot is **bitwise identical** to a single
//! unsharded [`DeltaFaq`] over the whole database.
//!
//! Routing follows the partition: a [`TupleDelta`] against the fact
//! relation goes to the one shard [`crate::faq::shard_of`] hashes its
//! values to (the shard that holds every other copy of that tuple, so
//! per-shard multiplicities never go negative), while deltas against
//! replicated dimension relations are broadcast to every shard — exactly
//! mirroring [`crate::faq::shard_databases`]. Per-shard batches run as
//! independent jobs on the shared [`ExecPool`](crate::util::exec::ExecPool),
//! largest batch first.
//!
//! After every batch the merged sorted snapshot is recomputed from the
//! per-shard snapshots and diffed against its predecessor, yielding one
//! composed [`StateSplice`] log (in application order) that keeps a
//! carried Step-4 [`EngineState`](crate::cluster::EngineState) aligned
//! with the merged grid — the same contract as
//! [`DeltaFaq::last_splices`].
//!
//! [`DeltaLayer`] wraps the single- and sharded-state flavors behind one
//! surface so the planner picks per [`super::PlannerOpts::shards`]
//! without branching at every call site.

use crate::cluster::StateSplice;
use crate::data::Database;
use crate::faq::{shard_databases, shard_of, GidAssigner, GridTable};
use crate::query::{Feq, JoinTree};
use crate::util::FxHashMap;
use anyhow::{Context, Result};
use std::cmp::Ordering;

use super::{DeltaFaq, PatchStats, TupleDelta};

/// A map of per-feature gid assigners, as [`DeltaFaq::apply`] consumes
/// it. Boxed assigner maps are not `Sync`, so the parallel entry points
/// take a `Sync` *factory* and build one map per pool job instead.
pub type AssignerMap<'m> = FxHashMap<String, Box<dyn GidAssigner + 'm>>;

/// S independent [`DeltaFaq`] states over the value-hashed fact shards,
/// plus the merged sorted grid snapshot and its composed splice log (see
/// module docs).
#[derive(Clone, Debug)]
pub struct ShardedDeltaFaq {
    /// The partitioned (fact) relation; everything else is replicated.
    fact: String,
    shards: Vec<DeltaFaq>,
    /// Merged snapshot: per-cell sum over shards, sorted by gid vector.
    sorted: Vec<(Vec<u32>, f64)>,
    feature_names: Vec<String>,
    /// Structural edits of the last [`ShardedDeltaFaq::apply`] against
    /// the previous merged snapshot, in application order.
    splices: Vec<StateSplice>,
}

impl ShardedDeltaFaq {
    /// Build per-shard delta states from scratch: partition the fact
    /// relation with [`shard_databases`], then run [`DeltaFaq::init`]
    /// per shard as independent pool jobs (largest fact shard first).
    /// The shared `tree` applies to every shard — shard databases keep
    /// the full relation set and schemas.
    pub fn init<'m, F>(
        db: &Database,
        feq: &Feq,
        tree: &JoinTree,
        shards: usize,
        make_assigners: F,
    ) -> Result<ShardedDeltaFaq>
    where
        F: Fn() -> AssignerMap<'m> + Sync,
    {
        let fact = feq.relations.first().context("FEQ names no relations")?.clone();
        let shard_dbs = shard_databases(db, &fact, shards)?;
        let mut order: Vec<usize> = (0..shard_dbs.len()).collect();
        order.sort_by_key(|&s| {
            std::cmp::Reverse(shard_dbs[s].get(&fact).map_or(0, |r| r.n_rows()))
        });
        let mut works: Vec<(Database, Option<Result<DeltaFaq>>)> =
            shard_dbs.into_iter().map(|sdb| (sdb, None)).collect();
        let pool = crate::util::exec::shared_pool();
        pool.run_chunks_ordered(&mut works, 0, &order, |_, (sdb, out)| {
            let assigners = make_assigners();
            *out = Some(DeltaFaq::init(sdb, feq, tree, &assigners));
        });
        let shards: Vec<DeltaFaq> = works
            .into_iter()
            .map(|(_, out)| out.expect("every shard init ran"))
            .collect::<Result<_>>()?;
        let feature_names = shards[0].grid_table().feature_names;
        let sorted = merge_cells(&shards);
        Ok(ShardedDeltaFaq { fact, shards, sorted, feature_names, splices: Vec::new() })
    }

    /// Patch all shards with one delta batch: route fact deltas by
    /// [`shard_of`], broadcast dimension deltas, apply the non-empty
    /// per-shard batches in parallel (largest first), then re-merge the
    /// sorted snapshot and derive the composed splice log. On error the
    /// state may be partially patched — the caller must rebuild, exactly
    /// as with [`DeltaFaq::apply`].
    pub fn apply<'m, F>(&mut self, deltas: &[TupleDelta], make_assigners: F) -> Result<PatchStats>
    where
        F: Fn() -> AssignerMap<'m> + Sync,
    {
        let s = self.shards.len();
        let mut batches: Vec<Vec<TupleDelta>> = vec![Vec::new(); s];
        for d in deltas {
            if d.relation == self.fact {
                batches[shard_of(&d.values, s)].push(d.clone());
            } else {
                for b in &mut batches {
                    b.push(d.clone());
                }
            }
        }

        let stats: Vec<Result<PatchStats>> = {
            let mut works: Vec<(&mut DeltaFaq, Vec<TupleDelta>, Option<Result<PatchStats>>)> =
                self.shards.iter_mut().zip(batches).map(|(d, b)| (d, b, None)).collect();
            let mut order: Vec<usize> = (0..works.len()).collect();
            order.sort_by_key(|&i| std::cmp::Reverse(works[i].1.len()));
            let pool = crate::util::exec::shared_pool();
            pool.run_chunks_ordered(&mut works, 0, &order, |_, (delta, batch, out)| {
                if batch.is_empty() {
                    // Untouched shard: its snapshot is unchanged, skip the
                    // empty apply (and the pool job's assigner build).
                    *out = Some(Ok(PatchStats::default()));
                    return;
                }
                let assigners = make_assigners();
                *out = Some(delta.apply(batch, &assigners));
            });
            works.into_iter().map(|(_, _, out)| out.expect("every shard job ran")).collect()
        };

        let mut agg = PatchStats { deltas: deltas.len(), ..PatchStats::default() };
        for st in stats {
            let st = st?;
            agg.cells_touched += st.cells_touched;
            agg.mass_delta_abs += st.mass_delta_abs;
        }
        let merged = merge_cells(&self.shards);
        self.splices = diff_splices(&self.sorted, &merged);
        self.sorted = merged;
        agg.grid_cells = self.sorted.len();
        agg.tombstone_ratio = self.tombstone_ratio();
        Ok(agg)
    }

    /// The merged patched grid (clone of the maintained snapshot), in the
    /// same sorted cell order as [`DeltaFaq::grid_table`].
    pub fn grid_table(&self) -> GridTable {
        GridTable { feature_names: self.feature_names.clone(), cells: self.sorted.clone() }
    }

    /// Structural edits the last [`ShardedDeltaFaq::apply`] made to the
    /// merged snapshot, in application order (the composed
    /// [`DeltaFaq::last_splices`] across shards).
    pub fn last_splices(&self) -> &[StateSplice] {
        &self.splices
    }

    /// Number of non-zero merged grid cells `|G|`.
    pub fn n_cells(&self) -> usize {
        self.sorted.len()
    }

    /// Total merged grid mass (= weighted `|X|`).
    pub fn mass(&self) -> f64 {
        self.sorted.iter().map(|(_, w)| w).sum()
    }

    /// Worst (maximum) per-shard tombstone ratio — compaction triggers
    /// when *any* shard's retained state has decayed.
    pub fn tombstone_ratio(&self) -> f64 {
        self.shards.iter().map(|s| s.tombstone_ratio()).fold(0.0, f64::max)
    }

    /// Compact every shard ([`DeltaFaq::compact`]). Returns `true` when
    /// all per-shard cell sets and orders survived — the merged snapshot
    /// is then unchanged and a carried engine state stays valid. On
    /// `false` the merged snapshot is recomputed and the splice log
    /// cleared; the caller must drop any carried state.
    #[must_use]
    pub fn compact(&mut self) -> bool {
        let mut ok = true;
        for s in &mut self.shards {
            ok &= s.compact();
        }
        if !ok {
            self.sorted = merge_cells(&self.shards);
            self.splices.clear();
        }
        ok
    }

    /// Number of shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Cap every shard's resident message tables at `budget` separator
    /// keys each (see [`DeltaFaq::set_spill_budget`]; 0 disables).
    pub fn set_spill_budget(&mut self, budget: usize) {
        for s in &mut self.shards {
            s.set_spill_budget(budget);
        }
    }

    /// Aggregate cold-key spill accounting across shards.
    pub fn spill_stats(&self) -> super::SpillStats {
        self.shards
            .iter()
            .map(|s| s.spill_stats())
            .fold(super::SpillStats::default(), |a, b| a.merged(b))
    }
}

/// Merged sorted cell list: per-cell weight is the sum of the per-shard
/// weights, accumulated in ascending shard order (deterministic; exact on
/// ring-ℤ weights). Per-shard snapshots hold only positive cells, so no
/// zero cells can appear in the sum. Shared with the epoch-close merge in
/// [`crate::ingest`].
pub(crate) fn merge_cells(shards: &[DeltaFaq]) -> Vec<(Vec<u32>, f64)> {
    let lists: Vec<Vec<(Vec<u32>, f64)>> =
        shards.iter().map(|s| s.grid_table().cells).collect();
    merge_cell_lists(&lists)
}

/// The list-level flavor of [`merge_cells`]: sum per-cell weights over
/// per-shard snapshot lists, accumulated in ascending list order. The
/// epoch-close merge works on retained snapshots rather than live
/// states, so it enters here.
pub(crate) fn merge_cell_lists(lists: &[Vec<(Vec<u32>, f64)>]) -> Vec<(Vec<u32>, f64)> {
    let mut acc: FxHashMap<Vec<u32>, f64> = FxHashMap::default();
    for list in lists {
        for (g, w) in list {
            *acc.entry(g.clone()).or_insert(0.0) += *w;
        }
    }
    crate::util::det::sorted_owned(acc)
}

/// Diff two sorted snapshots into a [`StateSplice`] log in application
/// order: positions refer to the evolving list as each edit lands, the
/// contract [`crate::cluster::EngineState::splice`] expects. Weight-only
/// changes emit nothing. Shared with the epoch-close diff in
/// [`crate::ingest`].
pub(crate) fn diff_splices(old: &[(Vec<u32>, f64)], new: &[(Vec<u32>, f64)]) -> Vec<StateSplice> {
    let mut ops = Vec::new();
    let (mut i, mut j, mut pos) = (0usize, 0usize, 0usize);
    while i < old.len() && j < new.len() {
        match old[i].0.cmp(&new[j].0) {
            Ordering::Equal => {
                i += 1;
                j += 1;
                pos += 1;
            }
            Ordering::Less => {
                ops.push(StateSplice::Remove(pos));
                i += 1;
            }
            Ordering::Greater => {
                ops.push(StateSplice::Insert(pos));
                pos += 1;
                j += 1;
            }
        }
    }
    while i < old.len() {
        ops.push(StateSplice::Remove(pos));
        i += 1;
    }
    while j < new.len() {
        ops.push(StateSplice::Insert(pos));
        pos += 1;
        j += 1;
    }
    ops
}

/// Single- or shard-parallel Step-3 delta state behind one surface — the
/// planner's [`IncrementalState`](super::IncrementalState) holds this and
/// the flavor follows `PlannerOpts::shards` at (re)build time. Both
/// flavors expose the identical patch contract (apply → splices →
/// grid table → compact), so the planner's decision procedure never
/// branches on the flavor.
#[derive(Clone, Debug)]
pub enum DeltaLayer {
    /// One [`DeltaFaq`] over the whole database (`shards <= 1`).
    Single(DeltaFaq),
    /// Per-shard states merged at the root.
    Sharded(ShardedDeltaFaq),
}

impl DeltaLayer {
    /// Build the flavor `shards` selects. The factory is invoked once on
    /// the single path, once per pool job on the sharded path.
    pub fn init<'m, F>(
        db: &Database,
        feq: &Feq,
        tree: &JoinTree,
        shards: usize,
        make_assigners: F,
    ) -> Result<DeltaLayer>
    where
        F: Fn() -> AssignerMap<'m> + Sync,
    {
        if shards <= 1 {
            let assigners = make_assigners();
            Ok(DeltaLayer::Single(DeltaFaq::init(db, feq, tree, &assigners)?))
        } else {
            Ok(DeltaLayer::Sharded(ShardedDeltaFaq::init(db, feq, tree, shards, make_assigners)?))
        }
    }

    /// Patch with one delta batch (see [`DeltaFaq::apply`] /
    /// [`ShardedDeltaFaq::apply`]). On error the state may be partially
    /// patched; the caller rebuilds.
    pub fn apply<'m, F>(&mut self, deltas: &[TupleDelta], make_assigners: F) -> Result<PatchStats>
    where
        F: Fn() -> AssignerMap<'m> + Sync,
    {
        match self {
            DeltaLayer::Single(d) => {
                let assigners = make_assigners();
                d.apply(deltas, &assigners)
            }
            DeltaLayer::Sharded(s) => s.apply(deltas, make_assigners),
        }
    }

    /// The patched grid (merged across shards on the sharded path).
    pub fn grid_table(&self) -> GridTable {
        match self {
            DeltaLayer::Single(d) => d.grid_table(),
            DeltaLayer::Sharded(s) => s.grid_table(),
        }
    }

    /// Structural edits of the last apply, in application order.
    pub fn last_splices(&self) -> &[StateSplice] {
        match self {
            DeltaLayer::Single(d) => d.last_splices(),
            DeltaLayer::Sharded(s) => s.last_splices(),
        }
    }

    /// Compact the retained state; `false` means the cell layout moved
    /// and any carried engine state must be dropped.
    #[must_use]
    pub fn compact(&mut self) -> bool {
        match self {
            DeltaLayer::Single(d) => d.compact(),
            DeltaLayer::Sharded(s) => s.compact(),
        }
    }

    /// Number of non-zero grid cells `|G|`.
    pub fn n_cells(&self) -> usize {
        match self {
            DeltaLayer::Single(d) => d.n_cells(),
            DeltaLayer::Sharded(s) => s.n_cells(),
        }
    }

    /// Total grid mass (= weighted `|X|`).
    pub fn mass(&self) -> f64 {
        match self {
            DeltaLayer::Single(d) => d.mass(),
            DeltaLayer::Sharded(s) => s.mass(),
        }
    }

    /// Shard count (1 on the single path).
    pub fn shard_count(&self) -> usize {
        match self {
            DeltaLayer::Single(_) => 1,
            DeltaLayer::Sharded(s) => s.shard_count(),
        }
    }

    /// Cap resident message tables per underlying state (see
    /// [`DeltaFaq::set_spill_budget`]; 0 disables spilling).
    pub fn set_spill_budget(&mut self, budget: usize) {
        match self {
            DeltaLayer::Single(d) => d.set_spill_budget(budget),
            DeltaLayer::Sharded(s) => s.set_spill_budget(budget),
        }
    }

    /// Cold-key spill accounting (aggregated on the sharded path).
    pub fn spill_stats(&self) -> super::SpillStats {
        match self {
            DeltaLayer::Single(d) => d.spill_stats(),
            DeltaLayer::Sharded(s) => s.spill_stats(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Attr, Relation, Schema, Value};
    use crate::faq::grid_weights;
    use crate::query::Hypergraph;
    use crate::util::SplitMix64;

    #[derive(Clone, Copy)]
    struct ModAssigner {
        n: u32,
        claimed: usize,
    }
    impl GidAssigner for ModAssigner {
        fn gid(&self, v: Value) -> u32 {
            let k = match v {
                Value::Double(x) => (x * 2.0) as i64 as u64,
                other => other.key_u64(),
            };
            (k % self.n as u64) as u32
        }
        fn n_gids(&self) -> usize {
            self.claimed
        }
    }

    fn assigners(n: u32, claimed: usize) -> AssignerMap<'static> {
        let mut m: AssignerMap<'static> = FxHashMap::default();
        for a in ["a", "b", "c"] {
            m.insert(a.to_string(), Box::new(ModAssigner { n, claimed }));
        }
        m
    }

    /// fact(a, b) ⋈ dim(b, c), big enough to populate several shards.
    fn setup(n_fact: usize, seed: u64) -> (Database, Feq, JoinTree) {
        let mut rng = SplitMix64::new(seed);
        let mut fact =
            Relation::new("fact", Schema::new(vec![Attr::cat("a", 8), Attr::cat("b", 8)]));
        for _ in 0..n_fact {
            fact.push_row(&[Value::Cat(rng.below(8) as u32), Value::Cat(rng.below(4) as u32)]);
        }
        let mut dim = Relation::new("dim", Schema::new(vec![Attr::cat("b", 8), Attr::cat("c", 8)]));
        for b in 0..4u32 {
            dim.push_row(&[Value::Cat(b), Value::Cat(b % 3)]);
        }
        let mut db = Database::new();
        db.add(fact);
        db.add(dim);
        let feq = Feq::with_features(&["fact", "dim"], &["a", "b", "c"]);
        let tree = Hypergraph::from_feq(&db, &feq).join_tree().unwrap();
        (db, feq, tree)
    }

    fn cells_bits(gt: &GridTable) -> Vec<(Vec<u32>, u64)> {
        gt.cells.iter().map(|(g, w)| (g.clone(), w.to_bits())).collect()
    }

    fn random_batch(rng: &mut SplitMix64, db: &Database, n: usize) -> Vec<TupleDelta> {
        let mut out = Vec::new();
        for _ in 0..n {
            if rng.below(5) < 2 {
                // Delete a live fact row (re-deriving liveness from the
                // relation keeps the stream valid under earlier deletes).
                let fact = db.get("fact").unwrap();
                let live: Vec<usize> =
                    (0..fact.n_rows()).filter(|&r| fact.weight(r) > 0.0).collect();
                if let Some(&r) = live.get(rng.below(live.len().max(1) as u64) as usize) {
                    out.push(TupleDelta::delete("fact", fact.row(r)));
                    continue;
                }
            }
            out.push(TupleDelta::insert(
                "fact",
                vec![Value::Cat(rng.below(8) as u32), Value::Cat(rng.below(4) as u32)],
            ));
        }
        out
    }

    #[test]
    fn sharded_init_is_bitwise_identical_to_single() {
        let (db, feq, tree) = setup(120, 1);
        let single = DeltaFaq::init(&db, &feq, &tree, &assigners(3, 3)).unwrap();
        for s in [1usize, 2, 3, 7] {
            let sharded =
                ShardedDeltaFaq::init(&db, &feq, &tree, s, || assigners(3, 3)).unwrap();
            assert_eq!(sharded.shard_count(), s);
            assert_eq!(
                cells_bits(&sharded.grid_table()),
                cells_bits(&single.grid_table()),
                "S = {s}"
            );
            assert_eq!(sharded.n_cells(), single.n_cells());
            assert_eq!(sharded.mass().to_bits(), single.mass().to_bits());
        }
    }

    #[test]
    fn sharded_patches_track_single_bitwise() {
        // Mixed insert/delete streams, fact and dimension deltas: after
        // every batch the merged sharded grid must be bitwise identical
        // to the unsharded delta state and to a from-scratch pass.
        let (mut db, feq, tree) = setup(150, 2);
        let mut single = DeltaFaq::init(&db, &feq, &tree, &assigners(3, 3)).unwrap();
        let mut sharded =
            ShardedDeltaFaq::init(&db, &feq, &tree, 3, || assigners(3, 3)).unwrap();
        let mut rng = SplitMix64::new(9);
        for round in 0..6 {
            let mut batch = random_batch(&mut rng, &db, 12);
            if round == 2 {
                // Dimension churn broadcasts to every shard.
                batch.push(TupleDelta::insert("dim", vec![Value::Cat(1), Value::Cat(7)]));
            }
            super::super::apply_to_db(&mut db, &batch).unwrap();
            let st1 = single.apply(&batch, &assigners(3, 3)).unwrap();
            let st2 = sharded.apply(&batch, || assigners(3, 3)).unwrap();
            assert_eq!(st1.deltas, st2.deltas, "round {round}");
            assert_eq!(
                cells_bits(&sharded.grid_table()),
                cells_bits(&single.grid_table()),
                "round {round}"
            );
            let scratch = grid_weights(&db, &feq, &tree, &assigners(3, 3)).unwrap();
            assert_eq!(cells_bits(&sharded.grid_table()), cells_bits(&scratch), "round {round}");
        }
    }

    #[test]
    fn splice_log_replays_the_merged_snapshot() {
        // Shadow replay: applying the composed splice log to the previous
        // cell list must reproduce the new cell list's shape (the
        // EngineState::splice contract).
        let (mut db, feq, tree) = setup(100, 3);
        let mut sharded =
            ShardedDeltaFaq::init(&db, &feq, &tree, 4, || assigners(3, 3)).unwrap();
        let mut shadow: Vec<Option<Vec<u32>>> =
            sharded.grid_table().cells.iter().map(|(g, _)| Some(g.clone())).collect();
        let mut rng = SplitMix64::new(17);
        for _ in 0..5 {
            let batch = random_batch(&mut rng, &db, 10);
            super::super::apply_to_db(&mut db, &batch).unwrap();
            sharded.apply(&batch, || assigners(3, 3)).unwrap();
            for sp in sharded.last_splices() {
                match *sp {
                    StateSplice::Insert(pos) => shadow.insert(pos, None),
                    StateSplice::Remove(pos) => {
                        shadow.remove(pos);
                    }
                }
            }
            let now = sharded.grid_table();
            assert_eq!(shadow.len(), now.cells.len());
            for (s, (g, _)) in shadow.iter_mut().zip(&now.cells) {
                match s {
                    // Surviving cells keep their identity...
                    Some(old) => assert_eq!(old, g),
                    // ...inserted slots adopt the new cell.
                    None => *s = Some(g.clone()),
                }
            }
        }
    }

    #[test]
    fn per_shard_multiplicities_stay_valid_under_delete_heavy_streams() {
        // Delete-heavy: routing deletes to the shard that holds the
        // matching inserts is what keeps every per-shard multiset
        // non-negative. Delete every remaining original row, then verify
        // against from-scratch.
        let (mut db, feq, tree) = setup(60, 4);
        let mut sharded =
            ShardedDeltaFaq::init(&db, &feq, &tree, 5, || assigners(3, 3)).unwrap();
        let rows: Vec<Vec<Value>> = {
            let fact = db.get("fact").unwrap();
            (0..fact.n_rows()).map(|r| fact.row(r)).collect()
        };
        for chunk in rows.chunks(7) {
            let batch: Vec<TupleDelta> =
                chunk.iter().map(|r| TupleDelta::delete("fact", r.clone())).collect();
            super::super::apply_to_db(&mut db, &batch).unwrap();
            sharded.apply(&batch, || assigners(3, 3)).unwrap();
        }
        assert_eq!(sharded.mass(), 0.0);
        assert_eq!(sharded.n_cells(), 0);
        // Tombstones dominate now; compaction must keep the (empty)
        // layout and report it survived.
        assert!(sharded.tombstone_ratio() > 0.0);
        assert!(sharded.compact());
        assert_eq!(sharded.n_cells(), 0);
    }

    #[test]
    fn shard_errors_propagate() {
        let (db, feq, tree) = setup(40, 5);
        let mut sharded =
            ShardedDeltaFaq::init(&db, &feq, &tree, 3, || assigners(3, 3)).unwrap();
        let err = sharded
            .apply(
                &[TupleDelta::delete("fact", vec![Value::Cat(7), Value::Cat(3)])],
                || assigners(3, 3),
            )
            .unwrap_err();
        assert!(err.to_string().contains("not present"), "got: {err}");
    }

    #[test]
    fn delta_layer_selects_flavor_and_matches() {
        let (mut db, feq, tree) = setup(90, 6);
        let mut one = DeltaLayer::init(&db, &feq, &tree, 1, || assigners(3, 3)).unwrap();
        let mut four = DeltaLayer::init(&db, &feq, &tree, 4, || assigners(3, 3)).unwrap();
        assert!(matches!(one, DeltaLayer::Single(_)));
        assert!(matches!(four, DeltaLayer::Sharded(_)));
        assert_eq!(one.shard_count(), 1);
        assert_eq!(four.shard_count(), 4);
        let mut rng = SplitMix64::new(23);
        for _ in 0..3 {
            let batch = random_batch(&mut rng, &db, 8);
            super::super::apply_to_db(&mut db, &batch).unwrap();
            one.apply(&batch, || assigners(3, 3)).unwrap();
            four.apply(&batch, || assigners(3, 3)).unwrap();
            assert_eq!(cells_bits(&one.grid_table()), cells_bits(&four.grid_table()));
            assert_eq!(one.mass().to_bits(), four.mass().to_bits());
        }
    }

    #[test]
    fn diff_splices_handles_all_shapes() {
        let cell = |g: u32, w: f64| (vec![g], w);
        // Weight-only change: no splices.
        assert!(diff_splices(&[cell(1, 1.0), cell(2, 1.0)], &[cell(1, 2.0), cell(2, 1.0)])
            .is_empty());
        // Pure insert at front, middle, back.
        assert_eq!(
            diff_splices(&[cell(2, 1.0)], &[cell(1, 1.0), cell(2, 1.0), cell(3, 1.0)]),
            vec![StateSplice::Insert(0), StateSplice::Insert(2)]
        );
        // Pure removal.
        assert_eq!(
            diff_splices(&[cell(1, 1.0), cell(2, 1.0), cell(3, 1.0)], &[cell(2, 1.0)]),
            vec![StateSplice::Remove(0), StateSplice::Remove(1)]
        );
        // Replacement at the same rank: remove-then-insert in order.
        assert_eq!(
            diff_splices(&[cell(1, 1.0), cell(3, 1.0)], &[cell(2, 1.0), cell(3, 1.0)]),
            vec![StateSplice::Remove(0), StateSplice::Insert(0)]
        );
        // Empty to empty and empty to full.
        assert!(diff_splices(&[], &[]).is_empty());
        assert_eq!(
            diff_splices(&[], &[cell(1, 1.0), cell(2, 1.0)]),
            vec![StateSplice::Insert(0), StateSplice::Insert(1)]
        );
    }
}
