//! k-median (`W₁` / ℓ1) extensions (paper §3, closing remark: "our
//! technique extends easily to the `W_p^p` objective for any p ≥ 1").
//!
//! * [`kmedian1d`] — optimal weighted 1-D k-median by dynamic programming
//!   with the same divide-and-conquer monotone-optimizer speedup as the
//!   k-means DP: segment cost = weighted absolute deviation around the
//!   weighted median, computable in O(log n) per segment from prefix sums.
//! * [`weighted_kmedian`] — dense alternating minimization (assign by ℓ1
//!   distance, update by coordinate-wise weighted median), the `W₁`
//!   analogue of Lloyd used to cluster coresets under the k-median
//!   objective.

use super::kmeanspp::kmeanspp_indices;
use crate::util::SplitMix64;

/// Result of an optimal 1-D k-median run.
#[derive(Clone, Debug)]
pub struct Kmedian1dResult {
    /// Cluster medians, ascending.
    pub centers: Vec<f64>,
    /// Midpoint decision boundaries (`centers.len() - 1` entries).
    pub boundaries: Vec<f64>,
    /// Optimal weighted ℓ1 cost Σ w·|v − median|.
    pub cost: f64,
}

impl Kmedian1dResult {
    /// Cluster id for a value.
    pub fn assign(&self, v: f64) -> u32 {
        self.boundaries.partition_point(|&b| b < v) as u32
    }
}

/// Prefix-sum oracle for weighted ℓ1 segment costs over sorted points.
struct L1Oracle {
    v: Vec<f64>,
    w: Vec<f64>,  // prefix weights
    wv: Vec<f64>, // prefix weight*value
}

impl L1Oracle {
    fn new(pts: &[(f64, f64)]) -> Self {
        let mut w = Vec::with_capacity(pts.len() + 1);
        let mut wv = Vec::with_capacity(pts.len() + 1);
        w.push(0.0);
        wv.push(0.0);
        for &(v, wt) in pts {
            w.push(w.last().expect("non-empty") + wt);
            wv.push(wv.last().expect("non-empty") + wt * v);
        }
        L1Oracle { v: pts.iter().map(|&(v, _)| v).collect(), w, wv }
    }

    /// Index of the weighted median of `[a, b)` (first index where the
    /// cumulative weight reaches half the segment mass).
    fn median_idx(&self, a: usize, b: usize) -> usize {
        let half = (self.w[a] + self.w[b]) / 2.0;
        // binary search over prefix weights
        let (mut lo, mut hi) = (a, b - 1);
        while lo < hi {
            let mid = (lo + hi) / 2;
            if self.w[mid + 1] < half {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        lo
    }

    /// Weighted ℓ1 cost of `[a, b)` around its weighted median.
    fn cost(&self, a: usize, b: usize) -> f64 {
        if b <= a {
            return 0.0;
        }
        let m = self.median_idx(a, b);
        let med = self.v[m];
        // left part [a, m]: med·W − ΣWV ; right part (m, b): ΣWV − med·W.
        let left = med * (self.w[m + 1] - self.w[a]) - (self.wv[m + 1] - self.wv[a]);
        let right = (self.wv[b] - self.wv[m + 1]) - med * (self.w[b] - self.w[m + 1]);
        (left + right).max(0.0)
    }

    fn median(&self, a: usize, b: usize) -> f64 {
        self.v[self.median_idx(a, b)]
    }
}

/// Optimal weighted 1-D k-median (duplicates merged, values sorted).
///
/// # Examples
///
/// ```
/// use rkmeans::cluster::kmedian1d;
///
/// // Two separated groups; the optimal 2-median splits them and puts
/// // each center at its group's weighted median.
/// let pts = [(0.0, 1.0), (1.0, 2.0), (2.0, 1.0), (10.0, 1.0), (11.0, 1.0)];
/// let r = kmedian1d(&pts, 2);
/// assert_eq!(r.centers, vec![1.0, 10.0]);
/// assert_eq!(r.assign(1.4), 0);
/// assert_eq!(r.assign(9.0), 1);
/// // cost = |0−1| + 2·|1−1| + |2−1| + |10−10| + |11−10| = 3
/// assert!((r.cost - 3.0).abs() < 1e-12);
/// ```
pub fn kmedian1d(points: &[(f64, f64)], k: usize) -> Kmedian1dResult {
    assert!(k >= 1, "k must be positive");
    let mut pts: Vec<(f64, f64)> = points.iter().copied().filter(|&(_, w)| w > 0.0).collect();
    pts.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite values"));
    let mut merged: Vec<(f64, f64)> = Vec::with_capacity(pts.len());
    for (v, w) in pts {
        match merged.last_mut() {
            Some((lv, lw)) if *lv == v => *lw += w,
            _ => merged.push((v, w)),
        }
    }
    if merged.is_empty() {
        return Kmedian1dResult { centers: vec![0.0], boundaries: vec![], cost: 0.0 };
    }
    let n = merged.len();
    if k >= n {
        let centers: Vec<f64> = merged.iter().map(|&(v, _)| v).collect();
        let boundaries = centers.windows(2).map(|w| 0.5 * (w[0] + w[1])).collect();
        return Kmedian1dResult { centers, boundaries, cost: 0.0 };
    }
    let oracle = L1Oracle::new(&merged);

    let mut prev: Vec<f64> = (0..=n).map(|i| oracle.cost(0, i)).collect();
    let mut splits: Vec<Vec<u32>> = vec![vec![0; n + 1]];
    for _j in 2..=k {
        let mut cur = vec![f64::INFINITY; n + 1];
        let mut opt = vec![0u32; n + 1];
        struct Frame {
            lo: usize,
            hi: usize,
            optlo: usize,
            opthi: usize,
        }
        let mut stack = vec![Frame { lo: 1, hi: n, optlo: 0, opthi: n - 1 }];
        while let Some(Frame { lo, hi, optlo, opthi }) = stack.pop() {
            if lo > hi {
                continue;
            }
            let mid = (lo + hi) / 2;
            let t_hi = opthi.min(mid - 1);
            let (mut best, mut best_t) = (f64::INFINITY, optlo);
            for t in optlo..=t_hi {
                let c = prev[t] + oracle.cost(t, mid);
                if c < best {
                    best = c;
                    best_t = t;
                }
            }
            cur[mid] = best;
            opt[mid] = best_t as u32;
            if mid > lo {
                stack.push(Frame { lo, hi: mid - 1, optlo, opthi: best_t });
            }
            if mid < hi {
                stack.push(Frame { lo: mid + 1, hi, optlo: best_t, opthi });
            }
        }
        prev = cur;
        splits.push(opt);
    }

    let mut cuts = Vec::with_capacity(k + 1);
    let mut end = n;
    for j in (0..k).rev() {
        cuts.push(end);
        end = splits[j][end] as usize;
    }
    cuts.push(0);
    cuts.reverse();
    let centers: Vec<f64> = (0..k).map(|s| oracle.median(cuts[s], cuts[s + 1])).collect();
    let boundaries = centers.windows(2).map(|w| 0.5 * (w[0] + w[1])).collect();
    Kmedian1dResult { centers, boundaries, cost: prev[n] }
}

/// Result of a dense weighted k-median run.
#[derive(Clone, Debug)]
pub struct KmedianResult {
    /// Row-major `k × d` medians.
    pub centroids: Vec<f64>,
    pub assign: Vec<u32>,
    /// Final weighted ℓ1 objective Σ w·‖x − C‖₁.
    pub objective: f64,
    pub iters: usize,
}

/// Dense weighted k-median: assign by ℓ1 distance, update each cluster's
/// center as the coordinate-wise weighted median.
///
/// # Examples
///
/// ```
/// use rkmeans::cluster::weighted_kmedian;
///
/// // Six 2-D points in two tight blobs, unit weights.
/// let pts = [0.0, 0.0, 0.0, 1.0, 1.0, 0.0, 9.0, 9.0, 9.0, 10.0, 10.0, 9.0];
/// let w = [1.0; 6];
/// let r = weighted_kmedian(&pts, &w, 2, 2, 25, 42);
/// // Each blob gets one label; the blobs get different labels.
/// assert_eq!(r.assign[0], r.assign[1]);
/// assert_eq!(r.assign[1], r.assign[2]);
/// assert_eq!(r.assign[3], r.assign[4]);
/// assert_eq!(r.assign[4], r.assign[5]);
/// assert_ne!(r.assign[0], r.assign[3]);
/// // Coordinate-wise medians: (0, 0) and (9, 9); ℓ1 objective 2 + 2.
/// assert!((r.objective - 4.0).abs() < 1e-12);
/// ```
pub fn weighted_kmedian(
    points: &[f64],
    weights: &[f64],
    d: usize,
    k: usize,
    max_iters: usize,
    seed: u64,
) -> KmedianResult {
    assert!(d > 0 && points.len() % d == 0);
    let n = points.len() / d;
    assert_eq!(weights.len(), n);
    let k = k.min(n);
    let row = |i: usize| &points[i * d..(i + 1) * d];
    let l1 = |a: &[f64], b: &[f64]| -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
    };

    let mut rng = SplitMix64::new(seed);
    // k-means++-style seeding with ℓ1 distances (D sampling).
    let seeds = kmeanspp_indices(n, weights, k, &mut rng, |i, j| l1(row(i), row(j)));
    let mut centroids: Vec<f64> = Vec::with_capacity(k * d);
    for &s in &seeds {
        centroids.extend_from_slice(row(s));
    }

    let mut assign = vec![0u32; n];
    let mut objective = f64::INFINITY;
    let mut iters = 0;
    for it in 0..max_iters.max(1) {
        iters = it + 1;
        let mut obj = 0.0;
        for i in 0..n {
            let x = row(i);
            let (mut best, mut bc) = (f64::INFINITY, 0u32);
            for c in 0..k {
                let dist = l1(x, &centroids[c * d..(c + 1) * d]);
                if dist < best {
                    best = dist;
                    bc = c as u32;
                }
            }
            assign[i] = bc;
            obj += weights[i] * best;
        }
        // Coordinate-wise weighted median per cluster.
        for c in 0..k {
            let members: Vec<usize> = (0..n).filter(|&i| assign[i] == c as u32).collect();
            if members.is_empty() {
                continue; // keep previous center
            }
            for j in 0..d {
                let mut vals: Vec<(f64, f64)> =
                    members.iter().map(|&i| (points[i * d + j], weights[i])).collect();
                vals.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite"));
                let half: f64 = vals.iter().map(|&(_, w)| w).sum::<f64>() / 2.0;
                let mut acc = 0.0;
                for &(v, w) in &vals {
                    acc += w;
                    if acc >= half {
                        centroids[c * d + j] = v;
                        break;
                    }
                }
            }
        }
        if objective.is_finite() && (objective - obj).abs() < 1e-12 {
            objective = obj;
            break;
        }
        objective = obj;
    }
    KmedianResult { centroids, assign, objective, iters }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::{assert_close, for_cases};

    /// Brute-force 1-D k-median over contiguous partitions.
    fn brute(pts: &[(f64, f64)], k: usize) -> f64 {
        let mut sorted: Vec<(f64, f64)> = pts.to_vec();
        sorted.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite"));
        let mut merged: Vec<(f64, f64)> = Vec::new();
        for (v, w) in sorted {
            match merged.last_mut() {
                Some((lv, lw)) if *lv == v => *lw += w,
                _ => merged.push((v, w)),
            }
        }
        let n = merged.len();
        let oracle = L1Oracle::new(&merged);
        let mut prev: Vec<f64> = (0..=n).map(|i| oracle.cost(0, i)).collect();
        for _ in 2..=k {
            let mut cur = vec![f64::INFINITY; n + 1];
            for i in 1..=n {
                for t in 0..i {
                    let c = prev[t] + oracle.cost(t, i);
                    if c < cur[i] {
                        cur[i] = c;
                    }
                }
            }
            prev = cur;
        }
        prev[n]
    }

    #[test]
    fn median_beats_mean_on_outliers() {
        // ℓ1: the outlier doesn't drag the center.
        let pts = vec![(0.0, 1.0), (1.0, 1.0), (2.0, 1.0), (100.0, 1.0)];
        let r = kmedian1d(&pts, 1);
        assert!(r.centers[0] <= 2.0, "median center {}", r.centers[0]);
        // cost = |0-1| + |1-1| + |2-1| + |100-1| = 101 (median at 1).
        assert_close(r.cost, 101.0, 1e-9);
    }

    #[test]
    fn dc_matches_bruteforce() {
        for_cases(30, |rng| {
            let n = 2 + rng.below(30) as usize;
            let k = 1 + rng.below(5) as usize;
            let pts: Vec<(f64, f64)> = (0..n)
                .map(|_| (rng.uniform(-10.0, 10.0), rng.uniform(0.1, 3.0)))
                .collect();
            let fast = kmedian1d(&pts, k);
            assert_close(fast.cost, brute(&pts, k), 1e-9);
        });
    }

    #[test]
    fn weighted_median_respects_mass() {
        // Heavy point pins the median.
        let pts = vec![(0.0, 10.0), (5.0, 1.0), (6.0, 1.0)];
        let r = kmedian1d(&pts, 1);
        assert_close(r.centers[0], 0.0, 1e-12);
    }

    #[test]
    fn k_ge_n_zero_cost() {
        let pts = vec![(1.0, 1.0), (5.0, 2.0)];
        let r = kmedian1d(&pts, 4);
        assert_eq!(r.cost, 0.0);
        assert_eq!(r.assign(4.0), 1);
    }

    #[test]
    fn dense_kmedian_clusters_and_descends() {
        let mut pts = Vec::new();
        for c in [0.0, 50.0] {
            for i in 0..20 {
                pts.push(c + (i % 5) as f64 * 0.1);
                pts.push(c - (i % 3) as f64 * 0.1);
            }
        }
        let w = vec![1.0; pts.len() / 2];
        let r = weighted_kmedian(&pts, &w, 2, 2, 20, 7);
        // Two far-apart blobs: objective far below one-cluster cost.
        let one = weighted_kmedian(&pts, &w, 2, 1, 20, 7);
        assert!(r.objective < 0.2 * one.objective, "{} vs {}", r.objective, one.objective);
    }

    #[test]
    fn dense_kmedian_objective_monotone() {
        for_cases(10, |rng| {
            let n = 15 + rng.below(30) as usize;
            let d = 1 + rng.below(3) as usize;
            let pts: Vec<f64> = (0..n * d).map(|_| rng.uniform(-5.0, 5.0)).collect();
            let w: Vec<f64> = (0..n).map(|_| rng.uniform(0.2, 2.0)).collect();
            let mut last = f64::INFINITY;
            for iters in 1..=4 {
                let r = weighted_kmedian(&pts, &w, d, 3, iters, 11);
                assert!(r.objective <= last + 1e-9);
                last = r.objective;
            }
        });
    }
}
