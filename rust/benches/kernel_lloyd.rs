//! Bench K1 — the Step-4 hot path across engines and shape buckets:
//! the bounds-pruned parallel engine vs. the naive serial reference on
//! synthetic blob shapes and on the materialized synthetic Retailer
//! workload (the acceptance target: n ≥ 100k, k ≥ 32), plus the XLA/PJRT
//! AOT path when built with `--features pjrt` and artifacts exist. Both
//! engine paths run in one invocation so the pruning speedup and skip
//! rates are directly visible, and all rows are written as one
//! `BENCH_lloyd.json` document per invocation (schema: see
//! `bench_harness` docs; path override: `RKMEANS_BENCH_OUT`).
//!
//! A **policy/precision ablation** runs on the same Retailer workload:
//! Hamerly vs Elkan at large k (acceptance: Elkan ≥ 1.3× pruned-Hamerly
//! assignment throughput at k ≥ 64), and the f32 tile vs the f64 kernel
//! on full scans (acceptance: ≥ 1.5× kernel throughput), emitted as
//! `retailer-ablation-*` rows next to the classic records.
//!
//! `--test` (or `--smoke`) shrinks everything for CI smoke runs.
//! `RKMEANS_BENCH_SCALE` overrides the Retailer scale (default 0.06 ≈
//! 120k join rows).

use rkmeans::bench_harness::{write_bench_lloyd, LloydBenchRecord};
use rkmeans::cluster::{weighted_lloyd_with, BoundsPolicy, EngineOpts, LloydConfig, Precision};
use rkmeans::join::{materialize, EmbedSpec};
use rkmeans::query::Hypergraph;
use rkmeans::synthetic::{retailer, Scale};
use rkmeans::util::SplitMix64;
use std::path::PathBuf;

/// Blob-structured synthetic points: the regime where assignments
/// stabilize after a few iterations (like real coresets), which is what
/// bounds pruning exploits. Uniform noise would understate the win.
fn synth(n: usize, d: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = SplitMix64::new(seed);
    let blobs = 8usize;
    let centers: Vec<f64> = (0..blobs * d).map(|_| rng.uniform(-8.0, 8.0)).collect();
    let mut pts = Vec::with_capacity(n * d);
    for _ in 0..n {
        let b = rng.below(blobs as u64) as usize;
        for j in 0..d {
            pts.push(centers[b * d + j] + 0.5 * rng.normal());
        }
    }
    let w: Vec<f64> = (0..n).map(|_| rng.uniform(0.2, 2.0)).collect();
    (pts, w)
}

/// Run naive-serial and pruned-parallel on one workload, assert they
/// agree exactly, print both rows, and record them.
fn run_pair(
    label: &str,
    pts: &[f64],
    w: &[f64],
    d: usize,
    k: usize,
    iters: usize,
    records: &mut Vec<LloydBenchRecord>,
) {
    let cfg = LloydConfig { k, max_iters: iters, tol: 0.0, seed: 3 };
    let (rn, sn) = weighted_lloyd_with(pts, w, d, &cfg, &EngineOpts::naive_serial());
    let (rp, sp) = weighted_lloyd_with(pts, w, d, &cfg, &EngineOpts::pruned());
    assert_eq!(
        rn.objective.to_bits(),
        rp.objective.to_bits(),
        "{label}: engine paths diverged"
    );
    assert!(rn.assign == rp.assign, "{label}: assignments diverged");
    let naive = LloydBenchRecord::from_stats(label, "dense-naive", d, k, rn.objective, &sn);
    let pruned = LloydBenchRecord::from_stats(label, "dense-pruned", d, k, rp.objective, &sp)
        .with_speedup_vs(&naive);
    println!("{}", naive.line());
    println!("{}\n", pruned.line());
    records.push(naive);
    records.push(pruned);
}

fn main() -> anyhow::Result<()> {
    let test_mode = std::env::args().any(|a| a == "--test" || a == "--smoke");
    let mut records: Vec<LloydBenchRecord> = Vec::new();

    // Synthetic shape sweep.
    let shapes: &[(usize, usize, usize)] = if test_mode {
        &[(1024, 8, 8), (4096, 16, 16)]
    } else {
        &[(4096, 16, 16), (16384, 32, 16), (65536, 16, 32)]
    };
    let iters = if test_mode { 3 } else { 10 };
    for &(n, d, k) in shapes {
        let (pts, w) = synth(n, d, 1);
        run_pair(&format!("synth-{n}x{d}"), &pts, &w, d, k, iters, &mut records);
    }

    // The acceptance workload: materialized synthetic Retailer (|X| =
    // fact rows; scale 0.06 ≈ 120k), dense engine, k ≥ 32.
    let scale: f64 = std::env::var("RKMEANS_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if test_mode { 0.002 } else { 0.06 });
    let (rk, riters) = if test_mode { (4usize, 3usize) } else { (32, 15) };
    let db = retailer::generate(Scale::custom(scale), 42);
    let feq = retailer::feq();
    let tree = Hypergraph::from_feq(&db, &feq).join_tree()?;
    let x = materialize(&db, &feq, &tree)?;
    let spec = EmbedSpec::from_feq(&db, &feq)?;
    let dense = spec.embed_matrix(&x);
    println!(
        "retailer workload: |X|={} rows × D={} (scale {scale}), k={rk}",
        x.len(),
        spec.dims
    );
    run_pair("retailer-materialized", &dense, &x.weights, spec.dims, rk, riters, &mut records);

    // Policy ablation: Hamerly vs Elkan on the same workload at large k
    // (where per-(point, centroid) bounds earn their O(n·k) memory).
    // Both arms are pruned + parallel; outputs must agree bitwise.
    let (abk, abiters) = if test_mode { (8usize, 3usize) } else { (64, 12) };
    let abcfg = LloydConfig { k: abk, max_iters: abiters, tol: 0.0, seed: 3 };
    let ham = EngineOpts::pruned().with_bounds(BoundsPolicy::Hamerly);
    let elk = EngineOpts::pruned().with_bounds(BoundsPolicy::Elkan);
    let (rh, sh) = weighted_lloyd_with(&dense, &x.weights, spec.dims, &abcfg, &ham);
    let (re, se) = weighted_lloyd_with(&dense, &x.weights, spec.dims, &abcfg, &elk);
    assert_eq!(
        rh.objective.to_bits(),
        re.objective.to_bits(),
        "bounds policies diverged"
    );
    assert!(rh.assign == re.assign, "bounds policies diverged on assignments");
    let ham_rec = LloydBenchRecord::from_stats(
        "retailer-ablation-bounds",
        "dense-pruned-hamerly",
        spec.dims,
        abk,
        rh.objective,
        &sh,
    );
    let elk_rec = LloydBenchRecord::from_stats(
        "retailer-ablation-bounds",
        "dense-pruned-elkan",
        spec.dims,
        abk,
        re.objective,
        &se,
    )
    .with_speedup_vs(&ham_rec);
    println!("{}", ham_rec.line());
    println!("{}\n", elk_rec.line());
    println!(
        "elkan vs hamerly @ k={abk}: {:.2}× points/sec (skip {:.1}% vs {:.1}%; target ≥ 1.3×)\n",
        elk_rec.speedup_vs_naive.unwrap_or(0.0),
        100.0 * elk_rec.skip_rate,
        100.0 * ham_rec.skip_rate
    );
    records.push(ham_rec);
    records.push(elk_rec);

    // Precision ablation: the f32 tile vs the f64 kernel on full scans
    // (naive mode, single thread — pure kernel throughput, no pruning or
    // scheduling noise). The objectives must agree to the documented f32
    // tolerance.
    let (pk, piters) = if test_mode { (8usize, 2usize) } else { (64, 4) };
    let pcfg = LloydConfig { k: pk, max_iters: piters, tol: 0.0, seed: 3 };
    let f64opts = EngineOpts::naive_serial();
    let f32opts = EngineOpts::naive_serial().with_precision(Precision::F32);
    let (r64, s64) = weighted_lloyd_with(&dense, &x.weights, spec.dims, &pcfg, &f64opts);
    let (r32, s32) = weighted_lloyd_with(&dense, &x.weights, spec.dims, &pcfg, &f32opts);
    let rel = (r64.objective - r32.objective).abs() / r64.objective.abs().max(1e-12);
    assert!(
        rel <= rkmeans::cluster::F32_OBJ_RTOL,
        "f32 objective drifted {rel:.2e} from f64"
    );
    let f64_rec = LloydBenchRecord::from_stats(
        "retailer-ablation-precision",
        "dense-naive-f64",
        spec.dims,
        pk,
        r64.objective,
        &s64,
    );
    let f32_rec = LloydBenchRecord::from_stats(
        "retailer-ablation-precision",
        "dense-naive-f32",
        spec.dims,
        pk,
        r32.objective,
        &s32,
    )
    .with_speedup_vs(&f64_rec);
    println!("{}", f64_rec.line());
    println!("{}\n", f32_rec.line());
    println!(
        "f32 tile vs f64 kernel @ k={pk}: {:.2}× points/sec (obj drift {rel:.1e}; target ≥ 1.5×)\n",
        f32_rec.speedup_vs_naive.unwrap_or(0.0)
    );
    records.push(f64_rec);
    records.push(f32_rec);

    // XLA/PJRT comparison rows when the artifact path is available.
    xla_rows(&mut records, test_mode);

    let out = PathBuf::from(
        std::env::var("RKMEANS_BENCH_OUT").unwrap_or_else(|_| "BENCH_lloyd.json".to_string()),
    );
    write_bench_lloyd(&out, &records)?;
    println!("wrote {} records to {}", records.len(), out.display());

    // The headline number the ROADMAP trajectory tracks.
    if let Some(r) = records
        .iter()
        .find(|r| r.label == "retailer-materialized" && r.engine == "dense-pruned")
    {
        println!(
            "retailer dense pruned vs naive: {:.2}× points/sec (skip rate {:.1}%)",
            r.speedup_vs_naive.unwrap_or(0.0),
            100.0 * r.skip_rate
        );
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn xla_rows(records: &mut Vec<LloydBenchRecord>, test_mode: bool) {
    use rkmeans::runtime::PjrtRuntime;
    let dir = PjrtRuntime::default_dir();
    if !PjrtRuntime::available(&dir) {
        println!("(no artifacts — XLA rows skipped; run `make artifacts`)\n");
        return;
    }
    let rt = match PjrtRuntime::load(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            println!("(XLA rows skipped: {e})\n");
            return;
        }
    };
    let (n, d, k, iters) = if test_mode { (1024, 8, 8, 3) } else { (16384, 32, 16, 10) };
    let (pts, w) = synth(n, d, 1);
    let cfg = LloydConfig { k, max_iters: iters, tol: 0.0, seed: 3 };
    let t0 = std::time::Instant::now();
    match rt.lloyd(&pts, &w, d, &cfg) {
        Ok(res) => {
            let wall = t0.elapsed().as_secs_f64();
            let rec = LloydBenchRecord {
                label: format!("synth-{n}x{d}"),
                engine: "dense-xla".to_string(),
                bounds: "none".to_string(),
                precision: "f32".to_string(),
                n,
                dims: d,
                k,
                iters: res.iters,
                wall_s: wall,
                points_per_sec: if wall > 0.0 { (n * res.iters) as f64 / wall } else { 0.0 },
                dist_evals: (n * k * res.iters) as u64,
                dist_evals_skipped: 0,
                skip_rate: 0.0,
                objective: res.objective,
                speedup_vs_naive: None,
            };
            println!("{}\n", rec.line());
            records.push(rec);
        }
        Err(e) => println!("(xla skipped: {e})\n"),
    }
}

#[cfg(not(feature = "pjrt"))]
fn xla_rows(_records: &mut Vec<LloydBenchRecord>, _test_mode: bool) {
    println!("(built without `pjrt` — XLA rows skipped)\n");
}
