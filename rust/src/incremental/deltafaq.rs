//! Delta maintenance of the Step-3 grid-weight FAQ (persistent InsideOut
//! message state).
//!
//! [`crate::faq::grid_weights`] evaluates the counting FAQ of Eq. 4 with
//! one upward pass whose per-node messages are discarded as the pass moves
//! up the join tree. [`DeltaFaq`] instead **retains** every message — a
//! sparse table per separator key over the gid-combinations of the node's
//! subtree — plus, per node, a multiset of its base tuples and an index
//! from each child-separator key to the tuples carrying it. A batch of
//! tuple inserts/deletes then propagates in time proportional to the
//! *touched* separator keys rather than `Õ(|D|)`:
//!
//! 1. deltas are grouped by tree node and processed in upward order;
//! 2. at each node, child message deltas are joined (via the key index)
//!    against only the tuples whose separator keys changed, using the
//!    telescoping product `Δ(T_1×…×T_p) = Σ_i T_1^new×…×ΔT_i×…×T_p^old`
//!    so multi-child nodes stay exact;
//! 3. the node's own inserted/deleted tuples contribute against the
//!    already-updated child messages, and deletes are just **negative
//!    weights** — the Step-3 FAQ lives in the ring ℤ, where retraction is
//!    the additive inverse (see the parent module docs);
//! 4. the root's message delta patches the sparse grid in place: cells
//!    whose weight reaches 0 are dropped, and a weight that goes negative
//!    aborts the patch (the ℤ-ring invariant was violated, e.g. by
//!    non-integer tuple weights drifting; the planner then rebuilds).
//!
//! Both combo-key paths of the batch evaluator are kept: the bit-packed
//! `u128` layout (the hot path) and the generic `Vec<u32>` fallback for
//! layouts over 128 bits, selected by the same bit-width rule as
//! [`grid_weights`](crate::faq::grid_weights). On ℤ-weighted databases
//! (integer tuple multiplicities below 2⁵³) every message entry is an
//! exactly-represented integer, so the maintained grid is **bitwise
//! identical** to a from-scratch evaluation — `tests/property_incremental.rs`
//! pins this for both key paths. With fractional weights the maintained
//! grid is exact up to FP re-association; the planner treats any root
//! negativity as corruption and falls back to a rebuild.
//!
//! The gid assigners passed to [`DeltaFaq::apply`] must be the *same*
//! Step-2 models the state was initialized with (stable gid maps are what
//! the marginal-drift trigger in [`super::marginal`] protects); a changed
//! bit layout is detected and rejected.
//!
//! ## Cold-key spilling
//!
//! Under a multi-shard ingest tier every shard holds its own retained
//! message state, so resident memory scales with shard count. When a
//! spill budget is set ([`DeltaFaq::set_spill_budget`], threaded from
//! `PlannerOpts::spill_budget`), separator-key message tables that have
//! not been touched recently spill to a per-state append-only disk
//! segment and are transparently reloaded the next time a batch touches
//! them. Spilling moves bytes, never values: the serialized table is
//! restored bit-for-bit (weights round-trip through `to_bits`), so a
//! spill-then-reload state stays **bitwise identical** to a never-spilled
//! one — `tests/property_ingest.rs` pins this under a tiny budget. The
//! root message (the grid itself) is never spilled, and
//! [`DeltaFaq::compact`] recomputes every message from the retained rows,
//! so compaction simply forgets the spill index. Cumulative counters are
//! exposed through [`DeltaFaq::spill_stats`].

use crate::cluster::StateSplice;
use crate::data::{AttrType, Database, Value};
use crate::faq::gridweights::GridTable;
use crate::faq::GidAssigner;
use crate::query::{Feq, JoinTree};
use crate::util::FxHashMap;
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::collections::hash_map::Entry;
use std::io::{Read, Seek, SeekFrom, Write};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::TupleDelta;

/// Statistics of one [`DeltaFaq::apply`] batch.
#[derive(Clone, Copy, Debug, Default)]
pub struct PatchStats {
    /// Deltas applied.
    pub deltas: usize,
    /// Root grid cells touched by the patch (created, changed or dropped).
    pub cells_touched: usize,
    /// Σ|Δweight| over the touched root cells — the exact join-level
    /// churn of this batch (feeds the planner's staleness backstop).
    pub mass_delta_abs: f64,
    /// Non-zero grid cells after the patch.
    pub grid_cells: usize,
    /// Tombstoned fraction after the patch: message entries and retained
    /// rows removed since the last (re)build, relative to the live count.
    /// Hash maps never release capacity on their own, so under
    /// delete-heavy load this is the resident-memory overhang
    /// [`DeltaFaq::compact`] reclaims (the planner's
    /// `incremental.tombstone_pm` metric / compaction trigger).
    pub tombstone_ratio: f64,
}

/// A gid-combination key: bit-packed `u128` on the hot path, a plain
/// per-feature `Vec<u32>` on the >128-bit fallback. Subtrees own disjoint
/// feature sets, so combining two subtree combos is a disjoint merge.
/// `Ord` gives the spill serializer a deterministic entry order;
/// `write_to`/`read_from` are its byte codec (exact round-trip).
trait Combo: Clone + Eq + Ord + std::hash::Hash {
    fn empty(layout: &Layout) -> Self;
    fn with_gid(self, fi: usize, gid: u32, layout: &Layout) -> Self;
    fn merge(&self, other: &Self) -> Self;
    fn unpack(&self, layout: &Layout) -> Vec<u32>;
    fn write_to(&self, out: &mut Vec<u8>);
    fn read_from(buf: &[u8], pos: &mut usize) -> Option<Self>;
}

/// Bit layout shared with [`crate::faq::grid_weights`]: feature `fi`
/// occupies `width` bits at `shift` (packed path only).
#[derive(Clone, Debug)]
struct Layout {
    n_features: usize,
    shifts: Vec<(u32, u32)>,
    total_bits: u32,
}

impl Layout {
    fn new(feq: &Feq, assigners: &FxHashMap<String, Box<dyn GidAssigner + '_>>) -> Layout {
        let mut shifts = Vec::with_capacity(feq.features.len());
        let mut total_bits = 0u32;
        for f in &feq.features {
            let kj = assigners[&f.attr].n_gids().max(2) as u64;
            let width = 64 - (kj - 1).leading_zeros().max(0);
            shifts.push((total_bits, width));
            total_bits += width;
        }
        Layout { n_features: feq.features.len(), shifts, total_bits }
    }
}

impl Combo for u128 {
    fn empty(_: &Layout) -> u128 {
        0
    }
    fn with_gid(self, fi: usize, gid: u32, layout: &Layout) -> u128 {
        self | (gid as u128) << layout.shifts[fi].0
    }
    fn merge(&self, other: &u128) -> u128 {
        self | other
    }
    fn unpack(&self, layout: &Layout) -> Vec<u32> {
        layout
            .shifts
            .iter()
            .map(|&(shift, width)| ((self >> shift) & ((1u128 << width) - 1)) as u32)
            .collect()
    }
    fn write_to(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn read_from(buf: &[u8], pos: &mut usize) -> Option<u128> {
        let bytes: [u8; 16] = buf.get(*pos..*pos + 16)?.try_into().ok()?;
        *pos += 16;
        Some(u128::from_le_bytes(bytes))
    }
}

impl Combo for Vec<u32> {
    fn empty(layout: &Layout) -> Vec<u32> {
        vec![0; layout.n_features]
    }
    fn with_gid(mut self, fi: usize, gid: u32, _: &Layout) -> Vec<u32> {
        self[fi] = gid;
        self
    }
    fn merge(&self, other: &Vec<u32>) -> Vec<u32> {
        // Owners are disjoint: at most one side is non-zero per position.
        self.iter().zip(other).map(|(a, b)| a | b).collect()
    }
    fn unpack(&self, _: &Layout) -> Vec<u32> {
        self.clone()
    }
    fn write_to(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&(self.len() as u32).to_le_bytes());
        for g in self {
            out.extend_from_slice(&g.to_le_bytes());
        }
    }
    fn read_from(buf: &[u8], pos: &mut usize) -> Option<Vec<u32>> {
        let len: [u8; 4] = buf.get(*pos..*pos + 4)?.try_into().ok()?;
        *pos += 4;
        let n = u32::from_le_bytes(len) as usize;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let bytes: [u8; 4] = buf.get(*pos..*pos + 4)?.try_into().ok()?;
            *pos += 4;
            out.push(u32::from_le_bytes(bytes));
        }
        Some(out)
    }
}

/// A message (or message delta): separator key → sparse combo table.
type Msg<K> = FxHashMap<Vec<u64>, FxHashMap<K, f64>>;

/// Cumulative + resident spill accounting of one [`DeltaFaq`] (see the
/// module docs; surfaced by the planner as `incremental.spill_*`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpillStats {
    /// Separator-key tables written to the spill segment, cumulative.
    pub spilled: u64,
    /// Tables transparently reloaded on touch, cumulative.
    pub reloaded: u64,
    /// Non-root message tables currently resident in memory.
    pub resident: usize,
    /// Tables currently parked on disk.
    pub on_disk: usize,
}

impl SpillStats {
    /// Elementwise sum — aggregates per-shard stats.
    pub fn merged(self, other: SpillStats) -> SpillStats {
        SpillStats {
            spilled: self.spilled + other.spilled,
            reloaded: self.reloaded + other.reloaded,
            resident: self.resident + other.resident,
            on_disk: self.on_disk + other.on_disk,
        }
    }
}

/// Process-unique suffix for spill segment paths (several states — one
/// per ingest shard — may spill concurrently).
static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);

/// An append-only on-disk segment holding spilled message tables. Shared
/// (`Arc`) across snapshot clones of a state — offsets stay valid because
/// nothing is ever overwritten; the file is unlinked when the last clone
/// drops. Stale bytes from re-spilled keys are accepted overhead (the
/// segment is bounded by churn, not by resident state).
#[derive(Debug)]
struct SpillFile {
    path: std::path::PathBuf,
    file: Mutex<std::fs::File>,
}

impl SpillFile {
    fn create() -> Result<SpillFile> {
        let path = std::env::temp_dir().join(format!(
            "rkmeans-spill-{}-{}.bin",
            std::process::id(),
            SPILL_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let file = std::fs::OpenOptions::new()
            .create(true)
            .read(true)
            .write(true)
            .truncate(true)
            .open(&path)
            .with_context(|| format!("create spill segment {}", path.display()))?;
        Ok(SpillFile { path, file: Mutex::new(file) })
    }

    fn append(&self, buf: &[u8]) -> Result<(u64, u32)> {
        let mut f = self.file.lock().map_err(|_| anyhow!("spill segment lock poisoned"))?;
        let off = f.seek(SeekFrom::End(0)).context("seek spill segment")?;
        f.write_all(buf).context("append spill segment")?;
        Ok((off, buf.len() as u32))
    }

    fn read(&self, off: u64, len: u32) -> Result<Vec<u8>> {
        let mut f = self.file.lock().map_err(|_| anyhow!("spill segment lock poisoned"))?;
        f.seek(SeekFrom::Start(off)).context("seek spill segment")?;
        let mut buf = vec![0u8; len as usize];
        f.read_exact(&mut buf).context("read spill segment")?;
        Ok(buf)
    }
}

impl Drop for SpillFile {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// Serialize one message table: entry count, then `(combo, weight-bits)`
/// records in ascending combo order (deterministic bytes, exact values).
fn encode_table<K: Combo>(table: &FxHashMap<K, f64>) -> Vec<u8> {
    // rklint::allow(nondet-iteration, reason = "entries are sorted by combo key before serialization; map order never reaches the spill segment")
    let mut entries: Vec<(&K, &f64)> = table.iter().collect();
    entries.sort_unstable_by(|a, b| a.0.cmp(b.0));
    let mut out = Vec::with_capacity(8 + entries.len() * 24);
    out.extend_from_slice(&(entries.len() as u64).to_le_bytes());
    for (g, w) in entries {
        g.write_to(&mut out);
        out.extend_from_slice(&w.to_bits().to_le_bytes());
    }
    out
}

/// Inverse of [`encode_table`]; bit-exact weights.
fn decode_table<K: Combo>(buf: &[u8]) -> Result<FxHashMap<K, f64>> {
    ensure!(buf.len() >= 8, "truncated spill record header");
    let n = u64::from_le_bytes(buf[..8].try_into().expect("8-byte slice")) as usize;
    let mut pos = 8usize;
    let mut table = FxHashMap::default();
    for _ in 0..n {
        let g = K::read_from(buf, &mut pos)
            .ok_or_else(|| anyhow!("truncated spill record combo"))?;
        let bytes: [u8; 8] = buf
            .get(pos..pos + 8)
            .ok_or_else(|| anyhow!("truncated spill record weight"))?
            .try_into()
            .expect("8-byte slice");
        pos += 8;
        table.insert(g, f64::from_bits(u64::from_le_bytes(bytes)));
    }
    Ok(table)
}

/// One retained base tuple (aggregated by value multiset).
#[derive(Clone, Debug)]
struct RowState<K> {
    /// Packed gids of the features this node owns.
    own: K,
    /// Aggregated multiplicity (> 0; rows at 0 are removed).
    w: f64,
    /// Separator key toward the parent.
    up_key: Vec<u64>,
    /// Separator key toward each child, in child order.
    child_keys: Vec<Vec<u64>>,
}

/// Persistent per-node state.
#[derive(Clone, Debug)]
struct NodeState<K> {
    /// (feature idx, column idx) of the features this node owns.
    owned: Vec<(usize, usize)>,
    /// Child node ids (fixed order — the telescoping order).
    children: Vec<usize>,
    /// Separator column indices in this relation, per child.
    child_cols: Vec<Vec<usize>>,
    /// Separator columns toward the parent.
    sep_cols: Vec<usize>,
    /// Column types, for delta validation and value encoding.
    col_types: Vec<AttrType>,
    /// Tuple multiset: encoded values → row state.
    rows: FxHashMap<Vec<u64>, RowState<K>>,
    /// Per child: separator key → encoded row keys carrying it (also
    /// indexes currently-dangling rows, which may start joining later).
    child_index: Vec<FxHashMap<Vec<u64>, Vec<Vec<u64>>>>,
    /// The retained upward message of this node.
    msg: Msg<K>,
}

#[derive(Clone, Debug)]
struct State<K> {
    layout: Layout,
    feature_names: Vec<String>,
    nodes: Vec<NodeState<K>>,
    /// Upward processing order (leaves first, root last).
    order: Vec<usize>,
    root: usize,
    rel_to_node: FxHashMap<String, usize>,
    /// The root grid as a cell list sorted by unpacked gid tuple,
    /// maintained incrementally by `apply` (one sort at init; patches
    /// splice only touched cells) so `grid_table` never re-sorts
    /// untouched runs.
    sorted: Vec<(Vec<u32>, f64)>,
    /// Structural edits the last `apply` made to `sorted`, in application
    /// order — the planner replays them onto its carried Step-4
    /// [`crate::cluster::EngineState`] so assignments/bounds stay aligned
    /// with the patched grid.
    splices: Vec<StateSplice>,
    /// Live message entries + retained rows (maintained incrementally).
    live: usize,
    /// Entries removed since init/compaction (tombstoned capacity).
    dead: usize,
    /// Max resident non-root message tables before cold keys spill
    /// (0 = spilling disabled).
    spill_budget: usize,
    /// The append-only disk segment (created on first spill; shared
    /// across snapshot clones).
    spill: Option<Arc<SpillFile>>,
    /// `(node, separator key)` → segment `(offset, len)` of tables
    /// currently parked on disk. Spilled entries still count as `live`:
    /// spilling moves residency, not liveness.
    spill_index: FxHashMap<(usize, Vec<u64>), (u64, u32)>,
    /// Last-touch logical stamp per resident key (only maintained while
    /// a budget is set); missing keys stamp 0, i.e. coldest.
    recency: FxHashMap<(usize, Vec<u64>), u64>,
    /// Logical access clock (bumped per touch; deterministic — batches
    /// touch keys in sorted order).
    clock: u64,
    /// Cumulative tables spilled / reloaded.
    spilled_n: u64,
    reloaded_n: u64,
}

/// Cross-product contribution of one tuple: `own × Π_j T_j(key_j)`, with
/// child `replace.0`'s table swapped for a delta table when given. `None`
/// when any required child key is (still) dangling.
fn contribution<K: Combo>(
    nodes: &[NodeState<K>],
    children: &[usize],
    own: &K,
    w: f64,
    child_keys: &[Vec<u64>],
    replace: Option<(usize, &FxHashMap<K, f64>)>,
) -> Option<Vec<(K, f64)>> {
    let mut combos: Vec<(K, f64)> = vec![(own.clone(), w)];
    for (j, &cj) in children.iter().enumerate() {
        let table = match replace {
            Some((rj, dtable)) if rj == j => dtable,
            _ => nodes[cj].msg.get(&child_keys[j])?,
        };
        if table.is_empty() {
            return None;
        }
        let mut next = Vec::with_capacity(combos.len() * table.len());
        for (prefix, pw) in &combos {
            for (g, gw) in table {
                next.push((prefix.merge(g), pw * gw));
            }
        }
        combos = next;
    }
    Some(combos)
}

/// Merge a message delta into a retained message, purging exact zeros so
/// the table keeps the same sparsity a from-scratch pass would produce.
/// `live`/`dead` track entry creations and removals for the tombstone
/// accounting (see [`PatchStats::tombstone_ratio`]).
fn merge_msg<K: Combo>(dst: &mut Msg<K>, src: Msg<K>, live: &mut usize, dead: &mut usize) {
    for (key, table) in src {
        let empty = {
            let slot = dst.entry(key.clone()).or_default();
            for (g, dw) in table {
                match slot.entry(g) {
                    Entry::Occupied(mut e) => *e.get_mut() += dw,
                    Entry::Vacant(e) => {
                        e.insert(dw);
                        *live += 1;
                    }
                }
            }
            let before = slot.len();
            slot.retain(|_, v| *v != 0.0);
            let removed = before - slot.len();
            *live -= removed;
            *dead += removed;
            slot.is_empty()
        };
        if empty {
            dst.remove(&key);
        }
    }
}

fn encode_value(v: &Value, ty: AttrType) -> Result<u64> {
    match (v, ty) {
        (Value::Int(x), AttrType::Int) => Ok(*x as u64),
        (Value::Cat(c), AttrType::Cat) => Ok(*c as u64),
        // Normalize -0.0 to +0.0 so the bit-keyed tuple multiset agrees
        // with `Relation::retract_row`'s `Value` equality (0.0 == -0.0).
        (Value::Double(x), AttrType::Double) => {
            Ok(if *x == 0.0 { 0.0f64.to_bits() } else { x.to_bits() })
        }
        _ => bail!("value {v} does not match column type {ty:?}"),
    }
}

impl<K: Combo> State<K> {
    fn init(
        db: &Database,
        feq: &Feq,
        tree: &JoinTree,
        assigners: &FxHashMap<String, Box<dyn GidAssigner + '_>>,
        layout: Layout,
    ) -> Result<State<K>> {
        let n = tree.len();
        let mut nodes: Vec<NodeState<K>> = Vec::with_capacity(n);
        let mut rel_to_node = FxHashMap::default();
        for u in 0..n {
            let rel = db
                .get(&tree.rel_names[u])
                .with_context(|| format!("relation {} missing", tree.rel_names[u]))?;
            let owned: Vec<(usize, usize)> = feq
                .features
                .iter()
                .enumerate()
                .filter(|(_, f)| feq.owner_of(db, &f.attr) == Some(u))
                .map(|(fi, f)| {
                    let col = rel.schema.index_of(&f.attr).expect("owner contains attr");
                    (fi, col)
                })
                .collect();
            let children = tree.children(u);
            let child_cols: Vec<Vec<usize>> = children
                .iter()
                .map(|&c| {
                    tree.sep[c]
                        .iter()
                        .map(|a| rel.schema.index_of(a).expect("separator attr in parent"))
                        .collect()
                })
                .collect();
            let sep_cols: Vec<usize> = tree.sep[u]
                .iter()
                .map(|a| rel.schema.index_of(a).expect("separator attr in node"))
                .collect();
            let n_children = children.len();
            rel_to_node.insert(tree.rel_names[u].clone(), u);
            nodes.push(NodeState {
                owned,
                children,
                child_cols,
                sep_cols,
                col_types: rel.schema.attrs().iter().map(|a| a.ty).collect(),
                rows: FxHashMap::default(),
                child_index: (0..n_children).map(|_| FxHashMap::default()).collect(),
                msg: FxHashMap::default(),
            });
        }

        let mut st = State {
            layout,
            feature_names: feq.features.iter().map(|f| f.attr.clone()).collect(),
            nodes,
            order: tree.order.clone(),
            root: tree.root,
            rel_to_node,
            sorted: Vec::new(),
            splices: Vec::new(),
            live: 0,
            dead: 0,
            spill_budget: 0,
            spill: None,
            spill_index: FxHashMap::default(),
            recency: FxHashMap::default(),
            clock: 0,
            spilled_n: 0,
            reloaded_n: 0,
        };

        // Upward pass, retaining rows, indexes and messages.
        for &u in &tree.order {
            let rel = db.get(&tree.rel_names[u]).expect("checked above");
            // Collect the tuple multiset.
            for row in 0..rel.n_rows() {
                let w = rel.weight(row);
                if w == 0.0 {
                    continue;
                }
                let node = &st.nodes[u];
                let mut own = K::empty(&st.layout);
                for &(fi, col) in &node.owned {
                    let gid = assigners[&st.feature_names[fi]].gid(rel.value(row, col));
                    own = own.with_gid(fi, gid, &st.layout);
                }
                let child_keys: Vec<Vec<u64>> = node
                    .child_cols
                    .iter()
                    .map(|cols| cols.iter().map(|&c| rel.col(c).key_u64(row)).collect())
                    .collect();
                let up_key: Vec<u64> =
                    node.sep_cols.iter().map(|&c| rel.col(c).key_u64(row)).collect();
                let rkey: Vec<u64> = (0..rel.n_cols())
                    .map(|c| {
                        encode_value(&rel.value(row, c), node.col_types[c])
                            .expect("schema types match their own columns")
                    })
                    .collect();
                let node = &mut st.nodes[u];
                match node.rows.entry(rkey.clone()) {
                    Entry::Occupied(mut e) => e.get_mut().w += w,
                    Entry::Vacant(e) => {
                        e.insert(RowState { own, w, up_key, child_keys: child_keys.clone() });
                        for (i, ck) in child_keys.iter().enumerate() {
                            node.child_index[i]
                                .entry(ck.clone())
                                .or_default()
                                .push(rkey.clone());
                        }
                    }
                }
            }
            // Compute this node's message from its rows + child messages.
            let mut msg: Msg<K> = FxHashMap::default();
            {
                let nodes = &st.nodes;
                let node = &nodes[u];
                // rklint::allow(nondet-iteration, reason = "ring-ℤ counting weights: every partial sum is an exactly-represented f64 integer, so accumulation is order-free (the patch ≡ rebuild bitwise contract in tests/property_incremental.rs pins this)")
                for row in node.rows.values() {
                    if let Some(combos) =
                        contribution(nodes, &node.children, &row.own, row.w, &row.child_keys, None)
                    {
                        let slot = msg.entry(row.up_key.clone()).or_default();
                        for (g, cw) in combos {
                            *slot.entry(g).or_insert(0.0) += cw;
                        }
                    }
                }
            }
            st.nodes[u].msg = msg;
        }

        // Seed the maintained sorted snapshot — the one O(|G| log |G|)
        // sort; `apply` keeps it sorted incrementally from here on.
        let empty_key: Vec<u64> = Vec::new();
        let mut cells: Vec<(Vec<u32>, f64)> = st.nodes[st.root]
            .msg
            .get(&empty_key)
            .map(|t| t.iter().map(|(g, &w)| (g.unpack(&st.layout), w)).collect())
            .unwrap_or_default();
        cells.sort_by(|a, b| a.0.cmp(&b.0));
        st.sorted = cells;
        st.live = st.count_live();
        st.dead = 0;
        Ok(st)
    }

    /// Live message entries + retained rows across every node (the
    /// tombstone-ratio denominator; recomputed only at init/compaction,
    /// maintained incrementally in between).
    fn count_live(&self) -> usize {
        self.nodes
            .iter()
            .map(|n| n.rows.len() + n.msg.values().map(|t| t.len()).sum::<usize>())
            .sum()
    }

    /// Encode one delta against node `u`'s schema: row key, own combo,
    /// child separator keys and parent separator key.
    #[allow(clippy::type_complexity)]
    fn row_parts(
        &self,
        u: usize,
        values: &[Value],
        assigners: &FxHashMap<String, Box<dyn GidAssigner + '_>>,
    ) -> Result<(Vec<u64>, K, Vec<Vec<u64>>, Vec<u64>)> {
        let node = &self.nodes[u];
        ensure!(
            values.len() == node.col_types.len(),
            "delta arity {} does not match relation arity {}",
            values.len(),
            node.col_types.len()
        );
        let rkey: Vec<u64> = values
            .iter()
            .zip(&node.col_types)
            .map(|(v, &ty)| encode_value(v, ty))
            .collect::<Result<_>>()?;
        let mut own = K::empty(&self.layout);
        for &(fi, col) in &node.owned {
            let gid = assigners[&self.feature_names[fi]].gid(values[col]);
            own = own.with_gid(fi, gid, &self.layout);
        }
        let child_keys: Vec<Vec<u64>> = node
            .child_cols
            .iter()
            .map(|cols| cols.iter().map(|&c| rkey[c]).collect())
            .collect();
        let up_key: Vec<u64> = node.sep_cols.iter().map(|&c| rkey[c]).collect();
        Ok((rkey, own, child_keys, up_key))
    }

    /// True when `apply` must run the touch/reload bookkeeping: either a
    /// budget is set, or earlier spills still sit on disk after the
    /// budget was lifted.
    fn spilling_active(&self) -> bool {
        self.spill_budget > 0 || !self.spill_index.is_empty()
    }

    /// Mark `(node, key)` pairs as hot, reloading any that are parked on
    /// disk. Pairs are sorted + deduped first so the recency stamps (and
    /// therefore later eviction choices) are independent of map
    /// iteration order at the call sites.
    fn touch_all(&mut self, mut pairs: Vec<(usize, Vec<u64>)>) -> Result<()> {
        pairs.sort_unstable();
        pairs.dedup();
        for (u, key) in pairs {
            self.clock += 1;
            let stamp = self.clock;
            if let Some((off, len)) = self.spill_index.remove(&(u, key.clone())) {
                let file =
                    self.spill.as_ref().ok_or_else(|| anyhow!("spill index without segment"))?;
                let table = decode_table::<K>(&file.read(off, len)?)?;
                self.nodes[u].msg.insert(key.clone(), table);
                self.reloaded_n += 1;
            }
            self.recency.insert((u, key), stamp);
        }
        Ok(())
    }

    /// Spill the coldest non-root message tables until the resident count
    /// is back under the budget. Victim order is deterministic:
    /// `(last-touch stamp, node, key)` ascending — never the root (the
    /// grid itself stays resident).
    fn enforce_spill_budget(&mut self) -> Result<()> {
        if self.spill_budget == 0 {
            return Ok(());
        }
        let root = self.root;
        let resident: usize = self
            .nodes
            .iter()
            .enumerate()
            .filter(|&(u, _)| u != root)
            .map(|(_, n)| n.msg.len())
            .sum();
        if resident <= self.spill_budget {
            return Ok(());
        }
        let mut candidates: Vec<(u64, usize, Vec<u64>)> = Vec::new();
        for u in 0..self.nodes.len() {
            if u == root {
                continue;
            }
            for key in crate::util::det::sorted_keys(&self.nodes[u].msg) {
                let stamp = self.recency.get(&(u, key.clone())).copied().unwrap_or(0);
                candidates.push((stamp, u, key));
            }
        }
        candidates.sort_unstable();
        let mut excess = resident - self.spill_budget;
        for (_, u, key) in candidates {
            if excess == 0 {
                break;
            }
            let Some(table) = self.nodes[u].msg.remove(&key) else { continue };
            let file = match &self.spill {
                Some(f) => Arc::clone(f),
                None => {
                    let f = Arc::new(SpillFile::create()?);
                    self.spill = Some(Arc::clone(&f));
                    f
                }
            };
            let slot = file.append(&encode_table(&table))?;
            self.spill_index.insert((u, key.clone()), slot);
            self.recency.remove(&(u, key));
            self.spilled_n += 1;
            excess -= 1;
        }
        Ok(())
    }

    fn spill_stats(&self) -> SpillStats {
        let root = self.root;
        SpillStats {
            spilled: self.spilled_n,
            reloaded: self.reloaded_n,
            resident: self
                .nodes
                .iter()
                .enumerate()
                .filter(|&(u, _)| u != root)
                .map(|(_, n)| n.msg.len())
                .sum(),
            on_disk: self.spill_index.len(),
        }
    }

    fn apply(
        &mut self,
        deltas: &[TupleDelta],
        assigners: &FxHashMap<String, Box<dyn GidAssigner + '_>>,
    ) -> Result<PatchStats> {
        self.splices.clear();
        let n = self.nodes.len();
        // Group deltas by node up front so unknown relations fail whole.
        let mut per_node: Vec<Vec<usize>> = (0..n).map(|_| Vec::new()).collect();
        for (i, d) in deltas.iter().enumerate() {
            let Some(&u) = self.rel_to_node.get(&d.relation) else {
                bail!("delta references relation {:?} outside the join tree", d.relation);
            };
            ensure!(d.weight != 0.0, "delta with zero weight for {:?}", d.relation);
            per_node[u].push(i);
        }

        let mut delta_msgs: Vec<Msg<K>> = (0..n).map(|_| FxHashMap::default()).collect();
        let order = self.order.clone();
        for &u in &order {
            let children = self.nodes[u].children.clone();
            let mut du: Msg<K> = FxHashMap::default();

            // Phase B: propagate child message deltas through the key
            // index (telescoping: earlier children new, later children
            // old; each child's stored message is updated right after its
            // delta has been consumed).
            for (ci, &c) in children.iter().enumerate() {
                let dm_c = std::mem::take(&mut delta_msgs[c]);
                if dm_c.is_empty() {
                    continue;
                }
                if self.spilling_active() {
                    // Touch set of this child's delta: the merge targets
                    // in child `c`'s message, plus every *other* child key
                    // the matched rows' telescoping products will read.
                    let mut pairs: Vec<(usize, Vec<u64>)> = Vec::new();
                    {
                        let node_u = &self.nodes[u];
                        for (key, dtable) in &dm_c {
                            pairs.push((c, key.clone()));
                            if dtable.is_empty() {
                                continue;
                            }
                            let Some(rowkeys) = node_u.child_index[ci].get(key) else { continue };
                            for rkey in rowkeys {
                                let Some(row) = node_u.rows.get(rkey) else { continue };
                                for (j, &cj) in children.iter().enumerate() {
                                    if j != ci {
                                        pairs.push((cj, row.child_keys[j].clone()));
                                    }
                                }
                            }
                        }
                    }
                    self.touch_all(pairs)?;
                }
                {
                    let nodes = &self.nodes;
                    let node_u = &nodes[u];
                    for (key, dtable) in &dm_c {
                        if dtable.is_empty() {
                            continue;
                        }
                        let Some(rowkeys) = node_u.child_index[ci].get(key) else { continue };
                        for rkey in rowkeys {
                            let Some(row) = node_u.rows.get(rkey) else { continue };
                            if let Some(combos) = contribution(
                                nodes,
                                &children,
                                &row.own,
                                row.w,
                                &row.child_keys,
                                Some((ci, dtable)),
                            ) {
                                let slot = du.entry(row.up_key.clone()).or_default();
                                for (g, cw) in combos {
                                    *slot.entry(g).or_insert(0.0) += cw;
                                }
                            }
                        }
                    }
                }
                merge_msg(&mut self.nodes[c].msg, dm_c, &mut self.live, &mut self.dead);
            }

            // Phase A: this node's own inserts/deletes, against the
            // now-updated child messages. Deletes are negative weights.
            for &di in &per_node[u] {
                let d = &deltas[di];
                let (rkey, own, child_keys, up_key) = self
                    .row_parts(u, &d.values, assigners)
                    .with_context(|| format!("bad delta for relation {:?}", d.relation))?;
                if self.spilling_active() {
                    let pairs: Vec<(usize, Vec<u64>)> = children
                        .iter()
                        .enumerate()
                        .map(|(j, &cj)| (cj, child_keys[j].clone()))
                        .collect();
                    self.touch_all(pairs)?;
                }
                {
                    let nodes = &self.nodes;
                    if let Some(combos) =
                        contribution(nodes, &children, &own, d.weight, &child_keys, None)
                    {
                        let slot = du.entry(up_key.clone()).or_default();
                        for (g, cw) in combos {
                            *slot.entry(g).or_insert(0.0) += cw;
                        }
                    }
                }
                let node = &mut self.nodes[u];
                match node.rows.entry(rkey.clone()) {
                    Entry::Occupied(mut e) => {
                        let nw = e.get().w + d.weight;
                        ensure!(
                            nw >= 0.0,
                            "retraction below zero multiplicity in {:?}",
                            d.relation
                        );
                        if nw == 0.0 {
                            let old = e.remove();
                            self.live -= 1;
                            self.dead += 1;
                            for (i, ck) in old.child_keys.iter().enumerate() {
                                if let Some(list) = node.child_index[i].get_mut(ck) {
                                    list.retain(|k| k != &rkey);
                                    if list.is_empty() {
                                        node.child_index[i].remove(ck);
                                    }
                                }
                            }
                        } else {
                            e.get_mut().w = nw;
                        }
                    }
                    Entry::Vacant(e) => {
                        ensure!(
                            d.weight > 0.0,
                            "delete of a tuple not present in {:?}",
                            d.relation
                        );
                        e.insert(RowState {
                            own,
                            w: d.weight,
                            up_key,
                            child_keys: child_keys.clone(),
                        });
                        self.live += 1;
                        for (i, ck) in child_keys.iter().enumerate() {
                            node.child_index[i].entry(ck.clone()).or_default().push(rkey.clone());
                        }
                    }
                }
            }

            delta_msgs[u] = du;
        }

        // Patch the root grid, asserting the ℤ-ring non-negativity, and
        // mirror every touched cell into the maintained sorted snapshot:
        // in-place for value changes, a binary-searched splice for
        // creations and drops — untouched runs are never re-sorted, and
        // every structural edit is logged in `splices` so the planner can
        // replay it onto its carried Step-4 engine state.
        let dm_root = std::mem::take(&mut delta_msgs[self.root]);
        let root = self.root;
        let mut cells_touched = 0usize;
        let mut mass_delta_abs = 0.0f64;
        for (key, table) in dm_root {
            cells_touched += table.len();
            // The root has no parent separator, so `key` is empty and the
            // message *is* the grid; the guard is defensive.
            let is_grid = key.is_empty();
            let empty = {
                let slot = self.nodes[root].msg.entry(key.clone()).or_default();
                for (g, dw) in table {
                    mass_delta_abs += dw.abs();
                    let v = match slot.entry(g.clone()) {
                        Entry::Occupied(e) => e.into_mut(),
                        Entry::Vacant(e) => {
                            self.live += 1;
                            e.insert(0.0)
                        }
                    };
                    *v += dw;
                    ensure!(
                        *v >= 0.0,
                        "incremental grid weight went negative at the root — the \
                         ℤ-ring invariant does not hold (fractional tuple weights \
                         drifted?); a full rebuild is required"
                    );
                    let nv = *v;
                    if nv == 0.0 {
                        slot.remove(&g);
                        self.live -= 1;
                        self.dead += 1;
                    }
                    if is_grid {
                        let uk = g.unpack(&self.layout);
                        match self.sorted.binary_search_by(|(a, _)| a.cmp(&uk)) {
                            Ok(pos) if nv == 0.0 => {
                                self.sorted.remove(pos);
                                self.splices.push(StateSplice::Remove(pos));
                            }
                            Ok(pos) => self.sorted[pos].1 = nv,
                            Err(pos) if nv != 0.0 => {
                                self.sorted.insert(pos, (uk, nv));
                                self.splices.push(StateSplice::Insert(pos));
                            }
                            Err(_) => {}
                        }
                    }
                }
                slot.is_empty()
            };
            if empty {
                self.nodes[root].msg.remove(&key);
            }
        }

        // Park the coldest tables back under the budget before reporting.
        self.enforce_spill_budget()?;

        Ok(PatchStats {
            deltas: deltas.len(),
            cells_touched,
            mass_delta_abs,
            grid_cells: self.n_cells(),
            tombstone_ratio: self.tombstone_ratio(),
        })
    }

    /// Tombstoned fraction: entries removed since the last (re)build
    /// relative to the live count (see [`PatchStats::tombstone_ratio`]).
    fn tombstone_ratio(&self) -> f64 {
        self.dead as f64 / self.live.max(1) as f64
    }

    /// Rebuild every retained collection tightly from the surviving
    /// tuple multisets: messages are recomputed bottom-up exactly like
    /// `init`'s upward pass, rows and key indexes are re-collected into
    /// fresh maps (hash maps never release capacity on their own), and
    /// the sorted grid snapshot is re-derived. On ℤ-weighted databases
    /// the result is bitwise-identical to the maintained state; with
    /// fractional weights it is exact up to FP re-association (the same
    /// caveat as the maintained state itself). Returns `true` when the
    /// grid's cell set and sorted order survived unchanged — the normal
    /// case, and what keeps a carried Step-4 engine state valid; `false`
    /// when FP re-association flipped some cell's zero-ness (fractional
    /// weights only), in which case the caller must drop any carried
    /// state (positions may have shifted with no splice log).
    fn compact(&mut self) -> bool {
        let old_keys: Vec<Vec<u32>> = self.sorted.iter().map(|(g, _)| g.clone()).collect();
        // The rebuild below recomputes every message from the retained
        // rows, so parked tables are regenerated resident; forget the
        // spill index (stale segment bytes go away when the state drops).
        self.spill_index.clear();
        self.recency.clear();
        let order = self.order.clone();
        for &u in &order {
            {
                let node = &mut self.nodes[u];
                let rows = std::mem::take(&mut node.rows);
                // rklint::allow(nondet-iteration, reason = "map-to-map rehash dropping tombstone capacity; iteration order never escapes the rebuilt map")
                node.rows = rows.into_iter().collect();
                for idx in node.child_index.iter_mut() {
                    let old = std::mem::take(idx);
                    *idx = old.into_iter().collect();
                }
            }
            // Recompute the upward message from rows + the already
            // recomputed child messages (children precede parents in
            // `order`).
            let mut msg: Msg<K> = FxHashMap::default();
            {
                let nodes = &self.nodes;
                let node = &nodes[u];
                // rklint::allow(nondet-iteration, reason = "ring-ℤ counting weights: exact integer f64 sums are order-free; compaction must reproduce the pre-compaction message bitwise")
                for row in node.rows.values() {
                    if let Some(combos) = contribution(
                        nodes,
                        &node.children,
                        &row.own,
                        row.w,
                        &row.child_keys,
                        None,
                    ) {
                        let slot = msg.entry(row.up_key.clone()).or_default();
                        for (g, cw) in combos {
                            *slot.entry(g).or_insert(0.0) += cw;
                        }
                    }
                }
            }
            self.nodes[u].msg = msg;
        }
        let empty_key: Vec<u64> = Vec::new();
        let mut cells: Vec<(Vec<u32>, f64)> = self.nodes[self.root]
            .msg
            .get(&empty_key)
            .map(|t| t.iter().map(|(g, &w)| (g.unpack(&self.layout), w)).collect())
            .unwrap_or_default();
        cells.sort_by(|a, b| a.0.cmp(&b.0));
        self.sorted = cells;
        self.live = self.count_live();
        self.dead = 0;
        self.sorted.len() == old_keys.len()
            && self.sorted.iter().zip(&old_keys).all(|((g, _), og)| g == og)
    }

    fn n_cells(&self) -> usize {
        let empty: Vec<u64> = Vec::new();
        self.nodes[self.root].msg.get(&empty).map(|t| t.len()).unwrap_or(0)
    }

    fn grid_table(&self) -> GridTable {
        GridTable { feature_names: self.feature_names.clone(), cells: self.sorted.clone() }
    }
}

enum Inner {
    Packed(State<u128>),
    Generic(State<Vec<u32>>),
}

impl Clone for Inner {
    fn clone(&self) -> Inner {
        match self {
            Inner::Packed(s) => Inner::Packed(s.clone()),
            Inner::Generic(s) => Inner::Generic(s.clone()),
        }
    }
}

/// Persistent Step-3 FAQ state supporting `apply(deltas)` (see module
/// docs). Cloneable, so [`super::IncrementalState`] snapshots are cheap
/// copies of the retained messages.
#[derive(Clone)]
pub struct DeltaFaq {
    inner: Inner,
}

impl DeltaFaq {
    /// Build the persistent message state for `db` with the given Step-2
    /// gid assigners (one per FEQ feature, keyed by attribute name — the
    /// same contract as [`crate::faq::grid_weights`]). Chooses the packed
    /// `u128` combo path when the gid bit layout fits 128 bits, the
    /// generic `Vec<u32>` path otherwise.
    pub fn init(
        db: &Database,
        feq: &Feq,
        tree: &JoinTree,
        assigners: &FxHashMap<String, Box<dyn GidAssigner + '_>>,
    ) -> Result<DeltaFaq> {
        for f in &feq.features {
            if !assigners.contains_key(&f.attr) {
                bail!("no gid assigner for feature {:?}", f.attr);
            }
        }
        let layout = Layout::new(feq, assigners);
        let inner = if layout.total_bits <= 128 {
            Inner::Packed(State::<u128>::init(db, feq, tree, assigners, layout)?)
        } else {
            Inner::Generic(State::<Vec<u32>>::init(db, feq, tree, assigners, layout)?)
        };
        Ok(DeltaFaq { inner })
    }

    /// Apply one batch of tuple deltas, patching the retained messages and
    /// the root grid. `assigners` must be the Step-2 models the state was
    /// initialized with (a changed bit layout is rejected). On error the
    /// state may be partially patched and must be re-initialized — the
    /// planner treats any `apply` error as a rebuild trigger.
    pub fn apply(
        &mut self,
        deltas: &[TupleDelta],
        assigners: &FxHashMap<String, Box<dyn GidAssigner + '_>>,
    ) -> Result<PatchStats> {
        let (layout, names) = match &self.inner {
            Inner::Packed(s) => (&s.layout, &s.feature_names),
            Inner::Generic(s) => (&s.layout, &s.feature_names),
        };
        ensure!(names.len() == layout.shifts.len(), "corrupt layout");
        for (name, &(_, width)) in names.iter().zip(&layout.shifts) {
            let asg = assigners
                .get(name)
                .with_context(|| format!("no gid assigner for feature {name:?}"))?;
            let kj = asg.n_gids().max(2) as u64;
            let need = 64 - (kj - 1).leading_zeros().max(0);
            ensure!(
                need <= width,
                "gid layout changed for feature {name:?} (Step-2 models moved); \
                 the incremental state must be rebuilt"
            );
        }
        match &mut self.inner {
            Inner::Packed(s) => s.apply(deltas, assigners),
            Inner::Generic(s) => s.apply(deltas, assigners),
        }
    }

    /// The maintained sparse grid, in deterministic (sorted) cell order.
    /// The sorted cell list is maintained *across* patches (one sort at
    /// init; each batch splices only its touched cells), so this snapshot
    /// is a plain O(|G|) copy — no per-batch re-sort of untouched runs —
    /// and the per-batch edit log ([`DeltaFaq::last_splices`]) lets the
    /// planner carry its Step-4 [`crate::cluster::EngineState`]
    /// (assignments + bounds) across the same edits.
    pub fn grid_table(&self) -> GridTable {
        match &self.inner {
            Inner::Packed(s) => s.grid_table(),
            Inner::Generic(s) => s.grid_table(),
        }
    }

    /// Number of non-zero grid cells `|G|`.
    pub fn n_cells(&self) -> usize {
        match &self.inner {
            Inner::Packed(s) => s.n_cells(),
            Inner::Generic(s) => s.n_cells(),
        }
    }

    /// Structural edits (inserts/drops, in application order) the last
    /// [`DeltaFaq::apply`] made to the sorted grid snapshot. Replay them
    /// onto a carried Step-4 state with
    /// [`crate::cluster::EngineState::splice`] so assignments and bounds
    /// stay aligned with the patched grid; weight-only cell changes are
    /// deliberately absent (they invalidate nothing).
    pub fn last_splices(&self) -> &[StateSplice] {
        match &self.inner {
            Inner::Packed(s) => &s.splices,
            Inner::Generic(s) => &s.splices,
        }
    }

    /// Tombstoned fraction of the retained state: message entries and
    /// rows removed since the last (re)build, relative to the live count
    /// — the resident-memory overhang [`DeltaFaq::compact`] reclaims.
    pub fn tombstone_ratio(&self) -> f64 {
        match &self.inner {
            Inner::Packed(s) => s.tombstone_ratio(),
            Inner::Generic(s) => s.tombstone_ratio(),
        }
    }

    /// Rebuild the retained collections tightly from the surviving tuple
    /// multisets, reclaiming tombstoned hash-map capacity (the planner
    /// triggers this when [`PatchStats::tombstone_ratio`] passes its
    /// threshold). On ℤ-weighted databases the compacted state is
    /// bitwise-identical to the maintained one and the grid's cell set
    /// and sorted order never change (returns `true`), so carried Step-4
    /// state stays valid. A `false` return means fractional-weight FP
    /// re-association changed some cell's zero-ness: the cell layout
    /// shifted with no splice log, and any carried Step-4 state must be
    /// dropped.
    #[must_use = "a false return means carried Step-4 state is now misaligned"]
    pub fn compact(&mut self) -> bool {
        match &mut self.inner {
            Inner::Packed(s) => s.compact(),
            Inner::Generic(s) => s.compact(),
        }
    }

    /// Total grid mass (= weighted `|X|`).
    pub fn mass(&self) -> f64 {
        self.grid_table().cells.iter().map(|(_, w)| w).sum()
    }

    /// True when the packed `u128` combo path is active.
    pub fn is_packed(&self) -> bool {
        matches!(self.inner, Inner::Packed(_))
    }

    /// Cap the resident non-root message tables at `budget` separator
    /// keys (0 disables spilling). Takes effect at the end of the next
    /// [`DeltaFaq::apply`]; already-parked tables keep reloading on touch
    /// even after the budget is lifted. Spilling is residency-only: the
    /// maintained grid stays bitwise identical to a never-spilled state.
    pub fn set_spill_budget(&mut self, budget: usize) {
        match &mut self.inner {
            Inner::Packed(s) => s.spill_budget = budget,
            Inner::Generic(s) => s.spill_budget = budget,
        }
    }

    /// Cold-key spill accounting (see [`SpillStats`]).
    pub fn spill_stats(&self) -> SpillStats {
        match &self.inner {
            Inner::Packed(s) => s.spill_stats(),
            Inner::Generic(s) => s.spill_stats(),
        }
    }
}

impl std::fmt::Debug for DeltaFaq {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DeltaFaq")
            .field("packed", &self.is_packed())
            .field("grid_cells", &self.n_cells())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Attr, Relation, Schema};
    use crate::faq::grid_weights;
    use crate::query::Hypergraph;

    /// Assigner mapping key -> key % n; `claimed` forces the generic path.
    struct ModAssigner {
        n: u32,
        claimed: usize,
    }
    impl GidAssigner for ModAssigner {
        fn gid(&self, v: Value) -> u32 {
            let k = match v {
                Value::Double(x) => (x * 2.0) as i64 as u64,
                other => other.key_u64(),
            };
            (k % self.n as u64) as u32
        }
        fn n_gids(&self) -> usize {
            self.claimed
        }
    }

    fn assigners(n: u32, claimed: usize) -> FxHashMap<String, Box<dyn GidAssigner>> {
        let mut m: FxHashMap<String, Box<dyn GidAssigner>> = FxHashMap::default();
        for a in ["a", "b", "c"] {
            m.insert(a.to_string(), Box::new(ModAssigner { n, claimed }));
        }
        m
    }

    /// fact(a, b) ⋈ dim(b, c).
    fn setup() -> (Database, Feq, JoinTree) {
        let mut fact =
            Relation::new("fact", Schema::new(vec![Attr::cat("a", 8), Attr::cat("b", 8)]));
        for (a, b) in [(0, 0), (1, 0), (2, 1), (3, 1), (4, 2)] {
            fact.push_row(&[Value::Cat(a), Value::Cat(b)]);
        }
        let mut dim = Relation::new("dim", Schema::new(vec![Attr::cat("b", 8), Attr::cat("c", 8)]));
        for (b, c) in [(0, 0), (0, 1), (1, 2), (2, 3)] {
            dim.push_row(&[Value::Cat(b), Value::Cat(c)]);
        }
        let mut db = Database::new();
        db.add(fact);
        db.add(dim);
        let feq = Feq::with_features(&["fact", "dim"], &["a", "b", "c"]);
        let tree = Hypergraph::from_feq(&db, &feq).join_tree().unwrap();
        (db, feq, tree)
    }

    fn cells_map(gt: &GridTable) -> FxHashMap<Vec<u32>, u64> {
        gt.cells.iter().map(|(g, w)| (g.clone(), w.to_bits())).collect()
    }

    #[test]
    fn init_matches_from_scratch_both_paths() {
        let (db, feq, tree) = setup();
        for claimed in [3usize, 1 << 60] {
            let asg = assigners(3, claimed);
            let delta = DeltaFaq::init(&db, &feq, &tree, &asg).unwrap();
            assert_eq!(delta.is_packed(), claimed == 3);
            let scratch = grid_weights(&db, &feq, &tree, &asg).unwrap();
            assert_eq!(cells_map(&delta.grid_table()), cells_map(&scratch));
        }
    }

    #[test]
    fn inserts_and_deletes_track_rebuilds() {
        let (mut db, feq, tree) = setup();
        let asg = assigners(3, 3);
        let mut delta = DeltaFaq::init(&db, &feq, &tree, &asg).unwrap();

        // Insert into both relations, delete one existing fact tuple.
        let batch = vec![
            TupleDelta::insert("fact", vec![Value::Cat(5), Value::Cat(2)]),
            TupleDelta::insert("dim", vec![Value::Cat(2), Value::Cat(5)]),
            TupleDelta::delete("fact", vec![Value::Cat(0), Value::Cat(0)]),
        ];
        delta.apply(&batch, &asg).unwrap();

        // Mirror on the database and rebuild from scratch.
        db.get_mut("fact").unwrap().push_row(&[Value::Cat(5), Value::Cat(2)]);
        db.get_mut("dim").unwrap().push_row(&[Value::Cat(2), Value::Cat(5)]);
        assert!(db.get_mut("fact").unwrap().retract_row(&[Value::Cat(0), Value::Cat(0)], 1.0));
        let scratch = grid_weights(&db, &feq, &tree, &asg).unwrap();
        assert_eq!(cells_map(&delta.grid_table()), cells_map(&scratch));
    }

    #[test]
    fn insert_then_delete_cancels_exactly() {
        let (db, feq, tree) = setup();
        let asg = assigners(3, 3);
        let mut delta = DeltaFaq::init(&db, &feq, &tree, &asg).unwrap();
        let before = cells_map(&delta.grid_table());
        let batch = vec![
            TupleDelta::insert("fact", vec![Value::Cat(7), Value::Cat(1)]),
            TupleDelta::delete("fact", vec![Value::Cat(7), Value::Cat(1)]),
        ];
        delta.apply(&batch, &asg).unwrap();
        assert_eq!(cells_map(&delta.grid_table()), before);
    }

    #[test]
    fn dangling_insert_joins_later() {
        let (db, feq, tree) = setup();
        let asg = assigners(3, 3);
        let mut delta = DeltaFaq::init(&db, &feq, &tree, &asg).unwrap();
        // b=5 has no dim rows: the fact insert is dangling for now.
        let mass0 = delta.mass();
        delta.apply(&[TupleDelta::insert("fact", vec![Value::Cat(1), Value::Cat(5)])], &asg)
            .unwrap();
        assert_eq!(delta.mass(), mass0);
        // Now a dim row arrives for b=5 and the pending fact row joins.
        delta.apply(&[TupleDelta::insert("dim", vec![Value::Cat(5), Value::Cat(0)])], &asg)
            .unwrap();
        assert_eq!(delta.mass(), mass0 + 1.0);
    }

    #[test]
    fn deleting_missing_tuple_is_an_error() {
        let (db, feq, tree) = setup();
        let asg = assigners(3, 3);
        let mut delta = DeltaFaq::init(&db, &feq, &tree, &asg).unwrap();
        let err = delta
            .apply(&[TupleDelta::delete("fact", vec![Value::Cat(6), Value::Cat(6)])], &asg)
            .unwrap_err();
        assert!(err.to_string().contains("not present"));
    }

    #[test]
    fn unknown_relation_and_bad_arity_rejected() {
        let (db, feq, tree) = setup();
        let asg = assigners(3, 3);
        let mut delta = DeltaFaq::init(&db, &feq, &tree, &asg).unwrap();
        assert!(delta
            .apply(&[TupleDelta::insert("nope", vec![Value::Cat(0)])], &asg)
            .is_err());
        assert!(delta
            .apply(&[TupleDelta::insert("fact", vec![Value::Cat(0)])], &asg)
            .is_err());
    }

    #[test]
    fn changed_gid_layout_is_rejected() {
        let (db, feq, tree) = setup();
        let asg = assigners(3, 3);
        let mut delta = DeltaFaq::init(&db, &feq, &tree, &asg).unwrap();
        // Wider layout than init: must be refused, not silently corrupted.
        let wide = assigners(3, 4000);
        let err = delta
            .apply(&[TupleDelta::insert("fact", vec![Value::Cat(0), Value::Cat(0)])], &wide)
            .unwrap_err();
        assert!(err.to_string().contains("layout changed"));
    }

    #[test]
    fn grid_snapshot_stays_sorted_across_patches() {
        // The sorted cell list is maintained incrementally: after every
        // batch (inserts creating new cells, deletes dropping cells) the
        // snapshot must still be strictly ordered and match a
        // from-scratch evaluation.
        let (mut db, feq, tree) = setup();
        let asg = assigners(3, 3);
        let mut delta = DeltaFaq::init(&db, &feq, &tree, &asg).unwrap();
        let batches = vec![
            vec![TupleDelta::insert("fact", vec![Value::Cat(5), Value::Cat(2)])],
            vec![TupleDelta::insert("dim", vec![Value::Cat(2), Value::Cat(5)])],
            vec![TupleDelta::delete("fact", vec![Value::Cat(0), Value::Cat(0)])],
        ];
        for batch in &batches {
            delta.apply(batch, &asg).unwrap();
            let gt = delta.grid_table();
            assert!(
                gt.cells.windows(2).all(|w| w[0].0 < w[1].0),
                "snapshot out of order after patch"
            );
        }
        // Mirror the batches on the database; the maintained snapshot
        // must equal a from-scratch evaluation bit-for-bit.
        db.get_mut("fact").unwrap().push_row(&[Value::Cat(5), Value::Cat(2)]);
        db.get_mut("dim").unwrap().push_row(&[Value::Cat(2), Value::Cat(5)]);
        assert!(db.get_mut("fact").unwrap().retract_row(&[Value::Cat(0), Value::Cat(0)], 1.0));
        let scratch = grid_weights(&db, &feq, &tree, &asg).unwrap();
        assert_eq!(cells_map(&delta.grid_table()), cells_map(&scratch));
    }

    #[test]
    fn splice_log_keeps_positions_aligned_with_snapshot() {
        // Replaying the per-batch splice ops onto a parallel array must
        // keep surviving entries aligned with the sorted snapshot — the
        // exact contract the planner's carried EngineState depends on.
        let (db, feq, tree) = setup();
        let asg = assigners(3, 3);
        let mut delta = DeltaFaq::init(&db, &feq, &tree, &asg).unwrap();
        // Shadow: the cell key each position carried before any patch.
        let mut shadow: Vec<Option<Vec<u32>>> =
            delta.grid_table().cells.iter().map(|(g, _)| Some(g.clone())).collect();
        let batches = vec![
            vec![TupleDelta::insert("fact", vec![Value::Cat(5), Value::Cat(2)])],
            vec![TupleDelta::delete("fact", vec![Value::Cat(0), Value::Cat(0)])],
            vec![
                TupleDelta::insert("dim", vec![Value::Cat(2), Value::Cat(5)]),
                TupleDelta::delete("fact", vec![Value::Cat(1), Value::Cat(0)]),
            ],
        ];
        for batch in &batches {
            delta.apply(batch, &asg).unwrap();
            for op in delta.last_splices() {
                match *op {
                    crate::cluster::StateSplice::Insert(pos) => shadow.insert(pos, None),
                    crate::cluster::StateSplice::Remove(pos) => {
                        shadow.remove(pos);
                    }
                }
            }
            let now = delta.grid_table();
            assert_eq!(shadow.len(), now.cells.len());
            for (s, (g, _)) in shadow.iter().zip(&now.cells) {
                if let Some(key) = s {
                    assert_eq!(key, g, "carried position drifted off its cell");
                }
            }
        }
    }

    #[test]
    fn tombstones_accumulate_and_compaction_is_exact() {
        let (db, feq, tree) = setup();
        let asg = assigners(3, 3);
        let mut delta = DeltaFaq::init(&db, &feq, &tree, &asg).unwrap();
        assert_eq!(delta.tombstone_ratio(), 0.0);
        // Delete-heavy churn: insert then retract the same tuples.
        for round in 0..4u32 {
            let vals = vec![Value::Cat(5 + (round % 2)), Value::Cat(2)];
            delta.apply(&[TupleDelta::insert("fact", vals.clone())], &asg).unwrap();
            delta.apply(&[TupleDelta::delete("fact", vals)], &asg).unwrap();
        }
        assert!(delta.tombstone_ratio() > 0.0, "churn must leave tombstones");
        let before = cells_map(&delta.grid_table());
        let ordered_before: Vec<Vec<u32>> =
            delta.grid_table().cells.iter().map(|(g, _)| g.clone()).collect();
        assert!(delta.compact(), "ℤ weights: compaction must preserve the cell layout");
        assert_eq!(delta.tombstone_ratio(), 0.0);
        // ℤ weights: the compacted grid is bitwise-identical, in the same
        // sorted order (carried engine state stays valid).
        assert_eq!(cells_map(&delta.grid_table()), before);
        let ordered_after: Vec<Vec<u32>> =
            delta.grid_table().cells.iter().map(|(g, _)| g.clone()).collect();
        assert_eq!(ordered_before, ordered_after);
        // And the state keeps patching correctly afterwards.
        let mut db = db;
        delta.apply(&[TupleDelta::insert("fact", vec![Value::Cat(7), Value::Cat(1)])], &asg)
            .unwrap();
        db.get_mut("fact").unwrap().push_row(&[Value::Cat(7), Value::Cat(1)]);
        let scratch = grid_weights(&db, &feq, &tree, &asg).unwrap();
        assert_eq!(cells_map(&delta.grid_table()), cells_map(&scratch));
    }

    #[test]
    fn spill_then_reload_is_bitwise_identical_both_paths() {
        // A tiny budget forces constant spill/reload churn; the grid must
        // stay bitwise identical to a never-spilled twin after every
        // batch, and after compaction (which forgets the spill index).
        let (db, feq, tree) = setup();
        for claimed in [3usize, 1 << 60] {
            let asg = assigners(3, claimed);
            let mut plain = DeltaFaq::init(&db, &feq, &tree, &asg).unwrap();
            let mut spilly = DeltaFaq::init(&db, &feq, &tree, &asg).unwrap();
            spilly.set_spill_budget(1);
            let batches = vec![
                vec![TupleDelta::insert("fact", vec![Value::Cat(5), Value::Cat(2)])],
                vec![TupleDelta::insert("dim", vec![Value::Cat(2), Value::Cat(5)])],
                vec![
                    TupleDelta::insert("dim", vec![Value::Cat(5), Value::Cat(1)]),
                    TupleDelta::delete("fact", vec![Value::Cat(0), Value::Cat(0)]),
                ],
                vec![TupleDelta::delete("dim", vec![Value::Cat(2), Value::Cat(5)])],
            ];
            for batch in &batches {
                plain.apply(batch, &asg).unwrap();
                spilly.apply(batch, &asg).unwrap();
                assert_eq!(
                    cells_map(&spilly.grid_table()),
                    cells_map(&plain.grid_table()),
                    "spilled state diverged (claimed={claimed})"
                );
            }
            let st = spilly.spill_stats();
            assert!(st.spilled > 0, "budget 1 must force spills (claimed={claimed})");
            assert!(st.reloaded > 0, "touches must reload parked tables (claimed={claimed})");
            assert!(st.resident <= 1, "budget must hold after apply (claimed={claimed})");
            assert_eq!(plain.spill_stats(), SpillStats::default());
            assert!(spilly.compact(), "ℤ weights: compaction preserves the layout");
            assert_eq!(spilly.spill_stats().on_disk, 0, "compaction forgets the index");
            assert_eq!(cells_map(&spilly.grid_table()), cells_map(&plain.grid_table()));
            // And patching keeps working after compaction re-residented all.
            let more = vec![TupleDelta::insert("fact", vec![Value::Cat(7), Value::Cat(1)])];
            plain.apply(&more, &asg).unwrap();
            spilly.apply(&more, &asg).unwrap();
            assert_eq!(cells_map(&spilly.grid_table()), cells_map(&plain.grid_table()));
        }
    }

    #[test]
    fn weighted_deltas_accumulate() {
        let (mut db, feq, tree) = setup();
        let asg = assigners(3, 3);
        let mut delta = DeltaFaq::init(&db, &feq, &tree, &asg).unwrap();
        let batch = vec![
            TupleDelta {
                relation: "fact".into(),
                values: vec![Value::Cat(0), Value::Cat(0)],
                weight: 3.0,
            },
            TupleDelta {
                relation: "fact".into(),
                values: vec![Value::Cat(0), Value::Cat(0)],
                weight: -2.0,
            },
        ];
        delta.apply(&batch, &asg).unwrap();
        db.get_mut("fact").unwrap().push_row(&[Value::Cat(0), Value::Cat(0)]);
        let scratch = grid_weights(&db, &feq, &tree, &asg).unwrap();
        assert_eq!(cells_map(&delta.grid_table()), cells_map(&scratch));
    }
}
