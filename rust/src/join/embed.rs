//! One-hot embedding of FEQ output rows into ℝ^D.
//!
//! Continuous and integer features map to one coordinate; categorical
//! features map to an indicator block of width `L` (the paper's categorical
//! subspace, §4.1 Eq. 28). Feature weights from the FEQ scale each block by
//! `√weight` so that squared distances are weighted per feature.
//!
//! The same spec is used by the materializing baseline (cluster the dense
//! `X`), the XLA/PJRT dense hot path, and full-objective evaluation.

use crate::data::{AttrType, Database, Value};
use crate::query::Feq;
use anyhow::{Context, Result};

use super::materialize::DataMatrix;

/// How one feature embeds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EmbKind {
    /// Single numeric coordinate (Double or Int features).
    Numeric,
    /// One-hot indicator block (Cat features).
    OneHot,
}

/// Embedding of one feature: a block `[offset, offset+width)` of the dense
/// vector, scaled by `scale = √feature_weight`.
#[derive(Clone, Debug)]
pub struct FeatEmb {
    pub name: String,
    pub kind: EmbKind,
    pub offset: usize,
    pub width: usize,
    pub scale: f64,
}

/// Full embedding specification for an FEQ.
#[derive(Clone, Debug)]
pub struct EmbedSpec {
    pub feats: Vec<FeatEmb>,
    /// Total dense dimensionality `D` (the paper's post-one-hot dimension).
    pub dims: usize,
}

impl EmbedSpec {
    /// Derive the embedding from the FEQ and schema. Categorical widths use
    /// the declared domain, falling back to `max observed id + 1`.
    pub fn from_feq(db: &Database, feq: &Feq) -> Result<Self> {
        let mut feats = Vec::with_capacity(feq.features.len());
        let mut offset = 0usize;
        for f in &feq.features {
            let owner = feq
                .owner_of(db, &f.attr)
                .with_context(|| format!("feature {:?} has no owner", f.attr))?;
            let rel = db.get(&feq.relations[owner]).expect("owner exists");
            let col = rel.schema.index_of(&f.attr).expect("attr in owner");
            let attr = rel.schema.attr(col);
            let (kind, width) = match attr.ty {
                AttrType::Double | AttrType::Int => (EmbKind::Numeric, 1),
                AttrType::Cat => {
                    let width = if attr.domain > 0 {
                        attr.domain as usize
                    } else {
                        // Infer from data.
                        (0..rel.n_rows())
                            .map(|r| rel.col(col).key_u64(r) as usize + 1)
                            .max()
                            .unwrap_or(1)
                    };
                    (EmbKind::OneHot, width)
                }
            };
            feats.push(FeatEmb {
                name: f.attr.clone(),
                kind,
                offset,
                width,
                scale: f.weight.sqrt(),
            });
            offset += width;
        }
        Ok(EmbedSpec { feats, dims: offset })
    }

    /// Embed one row (values in feature order) into `out` (length `dims`).
    pub fn embed_into(&self, vals: &[Value], out: &mut [f64]) {
        debug_assert_eq!(vals.len(), self.feats.len());
        debug_assert_eq!(out.len(), self.dims);
        out.fill(0.0);
        for (fe, v) in self.feats.iter().zip(vals.iter()) {
            match fe.kind {
                EmbKind::Numeric => out[fe.offset] = fe.scale * v.as_f64(),
                EmbKind::OneHot => {
                    let id = v.as_cat().expect("one-hot feature must be categorical") as usize;
                    debug_assert!(id < fe.width, "cat id {id} out of domain {}", fe.width);
                    out[fe.offset + id] = fe.scale;
                }
            }
        }
    }

    /// Embed a whole materialized matrix (row-major `|X| × dims`).
    pub fn embed_matrix(&self, x: &DataMatrix) -> Vec<f64> {
        let mut out = vec![0.0; x.len() * self.dims];
        for (i, row) in x.rows.iter().enumerate() {
            self.embed_into(row, &mut out[i * self.dims..(i + 1) * self.dims]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Attr, Relation, Schema};
    use crate::query::FeatureSpec;

    fn setup() -> (Database, Feq) {
        let mut r = Relation::new(
            "t",
            Schema::new(vec![Attr::cat("c", 3), Attr::double("x"), Attr::int("n")]),
        );
        r.push_row(&[Value::Cat(1), Value::Double(2.0), Value::Int(7)]);
        let mut db = Database::new();
        db.add(r);
        let feq = Feq::new(
            &["t"],
            vec![FeatureSpec::new("c"), FeatureSpec::weighted("x", 4.0), FeatureSpec::new("n")],
        );
        (db, feq)
    }

    #[test]
    fn layout_and_embedding() {
        let (db, feq) = setup();
        let spec = EmbedSpec::from_feq(&db, &feq).unwrap();
        assert_eq!(spec.dims, 3 + 1 + 1);
        assert_eq!(spec.feats[0].kind, EmbKind::OneHot);
        assert_eq!(spec.feats[1].offset, 3);
        let mut out = vec![0.0; spec.dims];
        spec.embed_into(&[Value::Cat(1), Value::Double(2.0), Value::Int(7)], &mut out);
        // one-hot block [0,1,0], then √4 * 2.0 = 4.0, then 7.
        assert_eq!(out, vec![0.0, 1.0, 0.0, 4.0, 7.0]);
    }

    #[test]
    fn inferred_domain_when_undeclared() {
        let mut r = Relation::new("t", Schema::new(vec![Attr::cat("c", 0)]));
        r.push_row(&[Value::Cat(4)]);
        let mut db = Database::new();
        db.add(r);
        let feq = Feq::with_features(&["t"], &["c"]);
        let spec = EmbedSpec::from_feq(&db, &feq).unwrap();
        assert_eq!(spec.dims, 5);
    }

    #[test]
    fn embed_matrix_is_row_major() {
        let (db, feq) = setup();
        let spec = EmbedSpec::from_feq(&db, &feq).unwrap();
        let x = DataMatrix {
            feature_names: vec!["c".into(), "x".into(), "n".into()],
            rows: vec![
                vec![Value::Cat(0), Value::Double(1.0), Value::Int(1)],
                vec![Value::Cat(2), Value::Double(0.0), Value::Int(2)],
            ],
            weights: vec![1.0, 1.0],
        };
        let m = spec.embed_matrix(&x);
        assert_eq!(m.len(), 2 * spec.dims);
        assert_eq!(&m[0..5], &[1.0, 0.0, 0.0, 2.0, 1.0]);
        assert_eq!(&m[5..10], &[0.0, 0.0, 1.0, 0.0, 2.0]);
    }
}
