//! Streaming ingestion + periodic re-clustering through the coordinator.
//!
//! ```sh
//! cargo run --release --offline --example streaming_pipeline
//! ```
//!
//! Simulates a Favorita-style deployment: sales tuples stream into the
//! fact table through a bounded (backpressured) channel while the
//! coordinator re-runs Rk-means every `RECLUSTER_EVERY` tuples and
//! publishes versioned clusterings. Because Rk-means only touches base
//! relations, each re-cluster is Õ(|D|) — no join is ever materialized.
//! Each published update also ships as a serialized `RkModel`, which a
//! serving replica restores and queries without any database.

use rkmeans::coordinator::{Coordinator, CoordinatorConfig};
use rkmeans::data::Value;
use rkmeans::rkmeans::{RkConfig, RkModel};
use rkmeans::synthetic::{favorita, Scale};
use rkmeans::util::SplitMix64;
use std::time::Duration;

const RECLUSTER_EVERY: usize = 3_000;
const BATCHES: usize = 4;

fn main() -> anyhow::Result<()> {
    let db = favorita::generate(Scale::small(), 7);
    let feq = favorita::feq();
    let sales_schema = db.get("sales").expect("sales relation").schema.clone();
    let n_dates = sales_schema.attr(0).domain as u64;
    let n_stores = sales_schema.attr(1).domain as u64;
    let n_items = sales_schema.attr(2).domain as u64;
    println!(
        "streaming into Favorita: {} base tuples, reclustering every {} new sales",
        db.total_rows(),
        RECLUSTER_EVERY
    );

    let mut cfg = CoordinatorConfig::new(RkConfig::new(8));
    cfg.recluster_every = RECLUSTER_EVERY;
    cfg.channel_capacity = 512; // small queue: demonstrates backpressure
    let coord = Coordinator::start(db, feq, cfg);

    // Producer: a new day of skewed sales per batch. A "replica" on the
    // side serves the latest shipped model while the writer keeps going.
    let mut rng = SplitMix64::new(99);
    let mut replica: Option<RkModel> = None;
    for batch in 0..BATCHES {
        for _ in 0..RECLUSTER_EVERY {
            let item = rng.below(n_items);
            let units = ((2.0 + rng.normal()).exp() * 100.0).round() / 100.0;
            coord.insert(
                "sales",
                vec![
                    Value::Cat(rng.below(n_dates) as u32),
                    Value::Cat(rng.below(n_stores) as u32),
                    Value::Cat(item as u32),
                    Value::Double(units),
                    Value::Cat(u32::from(rng.coin(0.08))),
                ],
            )?; // blocks if the coordinator is behind (backpressure)
        }
        match coord.recv_update(Duration::from_secs(300)) {
            Some(u) => {
                println!(
                    "update v{} after {:>6} tuples: |G|={:<7} objective={:.4e}  (job {:?})",
                    u.version, u.ingested, u.result.grid_points, u.result.objective_grid, u.elapsed
                );
                // Writer side: serialize the model; replica side: restore.
                // (In production the bytes cross a wire; here, a variable.)
                let bytes = u.model().to_bytes();
                replica = Some(RkModel::from_bytes(&bytes)?);
            }
            None => println!("batch {batch}: no update within timeout"),
        }
    }

    // The replica assigns a fresh (never-materialized) tuple — feature
    // values in FEQ order — without touching any database. The model
    // itself says which features are continuous vs. categorical.
    if let Some(replica) = &replica {
        use rkmeans::coreset::SubspaceSolver;
        let tuple: Vec<Value> = replica
            .models
            .iter()
            .map(|m| match &m.solver {
                SubspaceSolver::Continuous(_) => Value::Double(12.0),
                SubspaceSolver::Categorical(_) => Value::Cat(0),
            })
            .collect();
        let (cluster, d2) = replica.assign_with_distance(&tuple);
        println!(
            "replica v{} serves: tuple -> cluster {cluster} (squared distance {d2:.4e})",
            replica.version
        );
    }

    println!("\n-- coordinator metrics --\n{}", coord.metrics().render());
    let final_db = coord.shutdown()?;
    println!(
        "final sales table: {} rows",
        final_db.get("sales").expect("sales relation").n_rows()
    );
    Ok(())
}
