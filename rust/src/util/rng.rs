//! Deterministic, dependency-free random number generation.
//!
//! The environment builds offline, so instead of the `rand` crate we ship a
//! small [SplitMix64](https://prng.di.unimi.it/splitmix64.c) generator plus
//! the samplers the synthetic workloads and k-means++ seeding need. All
//! generators are explicitly seeded so every experiment is reproducible.

/// SplitMix64 PRNG — tiny, fast, passes BigCrush when used as a stream.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self) -> Self {
        SplitMix64::new(self.next_u64() ^ 0x9e37_79b9_7f4a_7c15)
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. `n` must be positive.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire-style rejection-free mapping is fine here (bias < 2^-64*n).
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean / standard deviation.
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Bernoulli with probability `p`.
    pub fn coin(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Sample an index from an (unnormalized, non-negative) weight slice.
    /// Returns `weights.len() - 1` on pathological all-zero input.
    pub fn weighted_index(&mut self, weights: &[f64], total: f64) -> usize {
        debug_assert!(!weights.is_empty());
        let mut target = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// Zipf(θ) sampler over `{0, …, n-1}` using the inverse-CDF over precomputed
/// cumulative weights. The synthetic fact tables (Inventory / Sales / Review)
/// use this to reproduce the real datasets' heavy skew, which is what drives
/// both the join-size blowup and the heavy/light categorical split.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Build a sampler for `n` items with exponent `theta` (0 = uniform).
    pub fn new(n: usize, theta: f64) -> Self {
        assert!(n > 0, "Zipf needs at least one item");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for i in 0..n {
            acc += 1.0 / ((i + 1) as f64).powf(theta);
            cdf.push(acc);
        }
        let norm = acc;
        for c in cdf.iter_mut() {
            *c /= norm;
        }
        Zipf { cdf }
    }

    /// Draw one item id.
    pub fn sample(&self, rng: &mut SplitMix64) -> usize {
        let u = rng.next_f64();
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).expect("cdf is finite"))
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    /// Number of items.
    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    /// True if the sampler is over an empty domain (never constructed).
    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = SplitMix64::new(7);
        let mut b = SplitMix64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = SplitMix64::new(1);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut rng = SplitMix64::new(2);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            let v = rng.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_mean_and_var_sane() {
        let mut rng = SplitMix64::new(3);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_is_skewed_and_ordered() {
        let mut rng = SplitMix64::new(4);
        let z = Zipf::new(100, 1.2);
        let mut counts = vec![0usize; 100];
        for _ in 0..100_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        // Head item dominates the tail item heavily under theta=1.2.
        assert!(counts[0] > 20 * counts[99].max(1));
        // And everything is in range by construction.
        assert_eq!(counts.iter().sum::<usize>(), 100_000);
    }

    #[test]
    fn zipf_theta_zero_is_uniformish() {
        let mut rng = SplitMix64::new(5);
        let z = Zipf::new(10, 0.0);
        let mut counts = vec![0usize; 10];
        for _ in 0..100_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 1_000.0, "count={c}");
        }
    }

    #[test]
    fn weighted_index_respects_mass() {
        let mut rng = SplitMix64::new(6);
        let w = [0.0, 3.0, 1.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[rng.weighted_index(&w, 4.0)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert!(counts[1] > 2 * counts[2]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = SplitMix64::new(8);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
