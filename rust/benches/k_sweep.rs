//! Bench W1 — k-sweep amortization through the staged pipeline: sweeping
//! k over **one shared `Coreset`** (Steps 1–3 paid once) vs. independent
//! one-shot `rkmeans()` calls (Steps 1–3 paid per k). κ is held fixed
//! across the sweep so both arms build the same grid, and the per-k
//! objectives are asserted **bitwise-identical** — the speedup is pure
//! reuse, not approximation. Results are written as one
//! `BENCH_sweep.json` document (schema: see `bench_harness` docs; path
//! override: `RKMEANS_SWEEP_OUT`). Acceptance target: shared-coreset
//! total ≥ 2× faster on the k ∈ {4, 8, 16, 32} Retailer sweep.
//!
//! `--test` (or `--smoke`) shrinks everything for CI smoke runs.
//! `RKMEANS_SWEEP_SCALE` overrides the Retailer scale (default 0.05).

use rkmeans::bench_harness::{write_bench_sweep, SweepBenchRecord};
use rkmeans::rkmeans::{rkmeans, ClusterOpts, RkConfig, RkPipeline, SubspaceOpts};
use rkmeans::synthetic::{retailer, Scale};
use std::path::PathBuf;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let test_mode = std::env::args().any(|a| a == "--test" || a == "--smoke");
    let scale: f64 = std::env::var("RKMEANS_SWEEP_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if test_mode { 0.003 } else { 0.05 });
    let ks: Vec<usize> = if test_mode { vec![2, 4, 8] } else { vec![4, 8, 16, 32] };
    let kappa = if test_mode { 8 } else { 16 };
    let seed = 42u64;

    let db = retailer::generate(Scale::custom(scale), seed);
    let feq = retailer::feq();
    println!(
        "sweep workload: |D|={} rows (scale {scale}), ks={ks:?}, κ={kappa}",
        db.total_rows()
    );

    // Arm 1: independent one-shot runs — Steps 1–3 recomputed per k.
    let mut indep_times = Vec::with_capacity(ks.len());
    let mut indep_objs = Vec::with_capacity(ks.len());
    let mut grid_cells = 0usize;
    let t_indep = Instant::now();
    for &k in &ks {
        let t0 = Instant::now();
        let res = rkmeans(&db, &feq, &RkConfig::new(k).with_kappa(kappa).with_seed(seed))?;
        indep_times.push(t0.elapsed().as_secs_f64());
        indep_objs.push(res.objective_grid);
        grid_cells = res.grid_points;
    }
    let indep_total = t_indep.elapsed().as_secs_f64();

    // Arm 2: staged — one shared coreset, Step 4 per k.
    let mut shared_times = Vec::with_capacity(ks.len());
    let mut shared_objs = Vec::with_capacity(ks.len());
    let t_shared = Instant::now();
    let pipe = RkPipeline::plan(&db, &feq)?;
    let marginals = pipe.marginals()?;
    let subspaces = pipe.subspaces(&marginals, &SubspaceOpts::new(kappa))?;
    let coreset = pipe.coreset(&subspaces)?;
    for &k in &ks {
        let t0 = Instant::now();
        let model = coreset.cluster(&ClusterOpts::new(k).with_seed(seed));
        shared_times.push(t0.elapsed().as_secs_f64());
        shared_objs.push(model.objective_grid);
    }
    let shared_total = t_shared.elapsed().as_secs_f64();

    // Exactness: identical per-k objectives, bitwise.
    for ((&k, a), b) in ks.iter().zip(&indep_objs).zip(&shared_objs) {
        anyhow::ensure!(
            a.to_bits() == b.to_bits(),
            "k={k}: objectives diverged (independent {a} vs shared {b})"
        );
    }

    let indep_rec = SweepBenchRecord::from_runs(
        "retailer",
        "independent",
        &ks,
        kappa,
        grid_cells,
        indep_total,
        &indep_times,
        &indep_objs,
    );
    let shared_rec = SweepBenchRecord::from_runs(
        "retailer",
        "shared-coreset",
        &ks,
        kappa,
        coreset.n(),
        shared_total,
        &shared_times,
        &shared_objs,
    )
    .with_speedup_vs(&indep_rec);
    println!("{}", indep_rec.line());
    println!("{}", shared_rec.line());

    let speedup = shared_rec.speedup_vs_independent.unwrap_or(0.0);
    let records = vec![indep_rec, shared_rec];
    let out = PathBuf::from(
        std::env::var("RKMEANS_SWEEP_OUT").unwrap_or_else(|_| "BENCH_sweep.json".to_string()),
    );
    write_bench_sweep(&out, &records)?;
    println!("wrote {} records to {}", records.len(), out.display());
    println!(
        "shared-coreset vs independent sweep total: {speedup:.2}× (acceptance target ≥ 2×, \
         identical per-k objectives)"
    );
    Ok(())
}
