//! Quickstart: cluster a relational dataset without materializing the
//! join — through the staged pipeline API.
//!
//! ```sh
//! cargo run --release --offline --example quickstart
//! ```
//!
//! Generates a small synthetic Retailer database (5 relations), stages
//! the pipeline once (plan → marginals → subspaces → coreset), sweeps k
//! over the shared coreset, and ships the winning model as bytes — the
//! 30-second tour of the public API. The one-shot `rkmeans()` wrapper
//! still exists for single runs; everything here is bitwise-identical to
//! it.

use rkmeans::rkmeans::{full_objective, ClusterOpts, RkModel, RkPipeline, SubspaceOpts};
use rkmeans::synthetic::{retailer, Scale};
use rkmeans::util::{human_bytes, human_count};

fn main() -> anyhow::Result<()> {
    // 1. A relational database: fact table + 4 dimension tables, with
    //    FD-chains (store -> zip -> city -> state).
    let db = retailer::generate(Scale::small(), 42);
    println!(
        "database: {} relations, {} tuples, {}",
        db.relations().len(),
        human_count(db.total_rows()),
        human_bytes(db.total_bytes())
    );

    // 2. The feature-extraction query: join everything, cluster on 16
    //    mixed categorical/continuous features.
    let feq = retailer::feq();
    println!("FEQ: {} features over {:?}", feq.n_features(), feq.relations);

    // 3. Stage the pipeline: Steps 1–3 run once and return reusable
    //    artifacts (marginals survive κ changes; the coreset survives
    //    every k).
    let pipe = RkPipeline::plan(&db, &feq)?;
    let marginals = pipe.marginals()?;
    let subspaces = pipe.subspaces(&marginals, &SubspaceOpts::new(10))?;
    let coreset = pipe.coreset(&subspaces)?;
    println!(
        "\nstaged: |X| = {} rows -> |G| = {} coreset cells \
         (step1 {:?}, step2 {:?}, step3 {:?})",
        human_count(marginals.output_size as u64),
        human_count(coreset.n() as u64),
        marginals.elapsed,
        subspaces.elapsed,
        coreset.elapsed
    );

    // 4. k-sweep over the shared coreset: only Step 4 runs per k.
    println!("\nk-sweep over one shared coreset:");
    for model in coreset.sweep(&[5, 10, 20], &ClusterOpts::new(0)) {
        println!(
            "  k={:<3} objective={:.4e}  iters={:<3} step4={:?}",
            model.k(),
            model.objective_grid,
            model.iters,
            model.timings.step4_cluster
        );
    }

    // 5. Pick one model; evaluate on the full (never materialized) join
    //    output and ship it as a self-contained serving payload.
    let model = coreset.cluster(&ClusterOpts::new(10));
    let res = model.clone().into_result();
    let full = full_objective(&db, &feq, &res)?;
    println!(
        "\nk=10: full-X objective {:.4e} (bound {:.4e}, quantization {:.4e})",
        full,
        res.objective_upper_bound(),
        model.quantization_cost
    );

    let bytes = model.to_bytes();
    let replica = RkModel::from_bytes(&bytes)?;
    println!(
        "serving: model -> {} bytes -> replica (k={}, m={}) with zero database access",
        human_count(bytes.len() as u64),
        replica.k(),
        replica.m()
    );
    Ok(())
}
