//! Bench K1 — the Step-4 hot path across engines and shape buckets:
//! native dense Lloyd (rust), the XLA/PJRT AOT artifact (Pallas kernel
//! under interpret=True), and the factored sparse Lloyd on an equivalent
//! synthetic grid. One Lloyd iteration per measurement (fixed work).

use rkmeans::bench_harness::bench;
use rkmeans::cluster::{weighted_lloyd, LloydConfig};
use rkmeans::runtime::PjrtRuntime;
use rkmeans::util::SplitMix64;

fn synth(n: usize, d: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = SplitMix64::new(seed);
    let pts: Vec<f64> = (0..n * d).map(|_| rng.uniform(-5.0, 5.0)).collect();
    let w: Vec<f64> = (0..n).map(|_| rng.uniform(0.2, 2.0)).collect();
    (pts, w)
}

fn main() -> anyhow::Result<()> {
    let shapes = [(1024usize, 8usize, 8usize), (4096, 16, 16), (16384, 32, 16), (65536, 16, 16)];
    let rt = if PjrtRuntime::available(&PjrtRuntime::default_dir()) {
        Some(PjrtRuntime::load(&PjrtRuntime::default_dir())?)
    } else {
        eprintln!("(no artifacts — XLA rows skipped; run `make artifacts`)");
        None
    };

    for (n, d, k) in shapes {
        let (pts, w) = synth(n, d, 1);
        let cfg = LloydConfig { k, max_iters: 1, tol: 0.0, seed: 3 };

        let mn = bench(&format!("native lloyd 1-iter N={n} D={d} K={k}"), 1, 5, || {
            weighted_lloyd(&pts, &w, d, &cfg)
        });
        println!("{}", mn.line());

        if let Some(rt) = &rt {
            match rt.lloyd(&pts, &w, d, &cfg) {
                Ok(_) => {
                    let mx = bench(&format!("xla    lloyd 1-iter N={n} D={d} K={k}"), 1, 5, || {
                        rt.lloyd(&pts, &w, d, &cfg).expect("xla lloyd")
                    });
                    println!("{}", mx.line());
                    println!("  -> native/xla: {:.2}×\n", mx.min() / mn.min());
                }
                Err(e) => println!("  (xla skipped: {e})\n"),
            }
        }
    }
    Ok(())
}
