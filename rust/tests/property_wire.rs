//! Byte-stability properties of the wire formats.
//!
//! The serving tier treats `RkModel::to_bytes` as a canonical encoding:
//! replicas compare payloads bitwise, deltas splice into snapshots
//! bit-exactly, and CI diffs dumps across runs. That only works if the
//! bytes are a function of the model's *content*, never of the
//! insertion order of the hash maps the pipeline happened to build it
//! from. These tests shuffle every order a caller can influence — the
//! Step-1 marginal map, the Step-3 grid cell list, metrics registration
//! order, JSON object construction order — and assert the bytes do not
//! move.

use rkmeans::coreset::{solve_subspaces, sparse_from_table, SubspaceModel};
use rkmeans::faq::{GridTable, Marginal};
use rkmeans::metrics::Metrics;
use rkmeans::rkmeans::{ClusterOpts, Coreset, RkModel, RkPipeline, SubspaceOpts};
use rkmeans::serve::ModelDelta;
use rkmeans::synthetic::{retailer, Scale};
use rkmeans::util::json::Json;
use rkmeans::util::FxHashMap;
use std::collections::BTreeMap;

const KAPPA: usize = 4;
const K: usize = 4;

/// Solve Step 2 from a marginal map populated in the given key order.
fn models_with_insertion_order(
    pipe_marginals: &[(String, Marginal)],
    order: impl Iterator<Item = usize>,
) -> Vec<SubspaceModel> {
    let feq = retailer::feq();
    let mut map: FxHashMap<String, Marginal> = FxHashMap::default();
    for i in order {
        let (attr, marg) = &pipe_marginals[i];
        map.insert(attr.clone(), marg.clone());
    }
    solve_subspaces(&feq, &map, KAPPA).expect("step 2")
}

/// Rebuild a `GridTable` from a canonical sparse grid, cells in the
/// order produced by `reorder`.
fn table_from_grid(coreset: &Coreset, reorder: impl Fn(&mut Vec<(Vec<u32>, f64)>)) -> GridTable {
    let m = coreset.grid.m;
    let mut cells: Vec<(Vec<u32>, f64)> = coreset
        .grid
        .gids
        .chunks(m)
        .zip(&coreset.grid.weights)
        .map(|(g, &w)| (g.to_vec(), w))
        .collect();
    reorder(&mut cells);
    let feature_names = retailer::feq().features.iter().map(|f| f.attr.clone()).collect();
    GridTable { feature_names, cells }
}

/// One full Step 2–4 run where the marginal map was populated in
/// `attr_order` and the grid cells arrive in `reorder` order.
fn model_variant(
    marginals: &[(String, Marginal)],
    base: &Coreset,
    attr_order: impl Iterator<Item = usize>,
    reorder: impl Fn(&mut Vec<(Vec<u32>, f64)>),
    version: u64,
) -> RkModel {
    let models = models_with_insertion_order(marginals, attr_order);
    let table = table_from_grid(base, reorder);
    let (grid, subspaces) = sparse_from_table(table, &models);
    Coreset::from_parts(grid, subspaces, models).cluster(&ClusterOpts::new(K)).with_version(version)
}

/// The shared fixture: one canonical pipeline run, plus the marginal
/// list in sorted-attr order so variants can permute it.
fn fixture() -> (Vec<(String, Marginal)>, Coreset) {
    let db = retailer::generate(Scale::tiny(), 42);
    let feq = retailer::feq();
    let pipe = RkPipeline::plan(&db, &feq).expect("plan");
    let marg = pipe.marginals().expect("step 1");
    let mut attrs: Vec<String> = feq.features.iter().map(|f| f.attr.clone()).collect();
    attrs.sort();
    attrs.dedup();
    let pairs: Vec<(String, Marginal)> =
        attrs.iter().map(|a| (a.clone(), marg.get(a).expect("marginal").clone())).collect();
    let subspaces = pipe.subspaces(&marg, &SubspaceOpts::new(KAPPA)).expect("step 2");
    let coreset = pipe.coreset(&subspaces).expect("step 3");
    (pairs, coreset)
}

#[test]
fn model_bytes_invariant_under_map_and_cell_order() {
    let (pairs, coreset) = fixture();
    let n = pairs.len();
    // Canonical: forward attr insertion, cells as produced.
    let a = model_variant(&pairs, &coreset, 0..n, |_| (), 1);
    // Adversarial: reversed attr insertion, cells reversed.
    let b = model_variant(&pairs, &coreset, (0..n).rev(), |cells| cells.reverse(), 1);
    // Adversarial: rotated attr insertion, cells rotated.
    let c = model_variant(
        &pairs,
        &coreset,
        (0..n).map(move |i| (i + n / 2) % n),
        |cells| {
            let cut = cells.len() / 2;
            cells.rotate_left(cut);
        },
        1,
    );
    let bytes = a.to_bytes();
    assert_eq!(bytes, b.to_bytes(), "reversed map/cell order changed the wire bytes");
    assert_eq!(bytes, c.to_bytes(), "rotated map/cell order changed the wire bytes");
    // And the bytes round-trip to a model that re-encodes identically.
    let back = RkModel::from_bytes(&bytes).expect("round trip");
    assert_eq!(back.to_bytes(), bytes, "decode/encode must be a fixed point");
}

#[test]
fn delta_sees_no_difference_between_shuffled_builds() {
    let (pairs, coreset) = fixture();
    let n = pairs.len();
    let base = model_variant(&pairs, &coreset, 0..n, |_| (), 1);
    let next = model_variant(&pairs, &coreset, (0..n).rev(), |cells| cells.reverse(), 2);
    // The two builds differ only in construction order, so the delta
    // engine (which compares parts bitwise) must ship zero parts.
    let delta = base.diff(&next);
    assert_eq!(delta.changes(), 0, "shuffled build produced content drift");
    // The empty delta itself has stable bytes and applies cleanly.
    let wire = delta.to_bytes();
    let decoded = ModelDelta::from_bytes(&wire).expect("delta decode");
    let applied = base.apply_delta(&decoded).expect("delta apply");
    assert_eq!(applied.to_bytes(), next.to_bytes(), "apply must land on the target bytes");
}

#[test]
fn rpc_frames_are_a_canonical_encoding_of_their_content() {
    use rkmeans::data::Value;
    use rkmeans::serve::rpc::wire::{self, kind};

    // The assign-plane row codec is a pure function of the values, and
    // decode ∘ encode is a fixed point (the same property the model
    // bytes pin above, extended to the socket tier's own format).
    let row = vec![Value::Int(-3), Value::Double(2.5), Value::Cat(7)];
    let enc = wire::encode_row(&row);
    let back = wire::decode_row(&enc).expect("row decode");
    assert_eq!(back, row);
    assert_eq!(wire::encode_row(&back), enc, "decode/encode must be a fixed point");

    // Snapshot frames wrap `RkModel::to_bytes` verbatim, so two builds
    // that only differ in construction order produce identical frames —
    // replica byte-verification depends on exactly this.
    let (pairs, coreset) = fixture();
    let n = pairs.len();
    let a = model_variant(&pairs, &coreset, 0..n, |_| (), 1);
    let b = model_variant(&pairs, &coreset, (0..n).rev(), |cells| cells.reverse(), 1);
    assert_eq!(
        wire::encode_frame(kind::SNAPSHOT, &a.to_bytes()),
        wire::encode_frame(kind::SNAPSHOT, &b.to_bytes()),
        "snapshot frames must inherit the model's byte stability"
    );
}

#[test]
fn metrics_dump_is_invariant_under_registration_order() {
    let forward = Metrics::new();
    forward.counter("serve.swaps").add(3);
    forward.gauge("serve.version").set(7);
    forward.histogram("serve.assign_us").observe(50);
    forward.histogram("serve.assign_us").observe(90);

    let reversed = Metrics::new();
    reversed.histogram("serve.assign_us").observe(50);
    reversed.histogram("serve.assign_us").observe(90);
    reversed.gauge("serve.version").set(7);
    reversed.counter("serve.swaps").add(3);

    assert_eq!(forward.snapshot(), reversed.snapshot());
    assert_eq!(
        forward.render().into_bytes(),
        reversed.render().into_bytes(),
        "rendered metrics dump must be byte-stable across registration orders"
    );
    // The dump is sorted, so its line order is part of the contract.
    let dump = forward.render();
    let lines: Vec<&str> = dump.lines().collect();
    let mut sorted = lines.clone();
    sorted.sort_unstable();
    assert_eq!(lines, sorted, "render() must emit sorted lines");
}

#[test]
fn json_objects_encode_with_sorted_keys_regardless_of_build_order() {
    let mut fwd = BTreeMap::new();
    fwd.insert("alpha".to_string(), Json::Num(1.0));
    fwd.insert("mid".to_string(), Json::Str("x".to_string()));
    fwd.insert("zeta".to_string(), Json::Bool(true));

    let mut rev = BTreeMap::new();
    rev.insert("zeta".to_string(), Json::Bool(true));
    rev.insert("mid".to_string(), Json::Str("x".to_string()));
    rev.insert("alpha".to_string(), Json::Num(1.0));

    let a = Json::Obj(fwd).to_string();
    let b = Json::Obj(rev).to_string();
    assert_eq!(a, b);
    assert!(a.find("alpha").unwrap() < a.find("zeta").unwrap(), "keys must serialize sorted");
}
