//! Synthetic **Retailer** (paper §5: 5 relations, 39 attrs, 95 one-hot;
//! used by a large US retailer for sales forecasting).
//!
//! Schema (faithful to the paper's description):
//! * `inventory(store, date, sku, units)` — the fact table, Zipf over skus;
//! * `location(store, zip, city, state, distance_comp, store_type)` — with
//!   the FD-chain `store → zip → city → state` (paper §4.2's example);
//! * `census(zip, population, income, median_age, house_units)`;
//! * `weather(store, date, temp, rain)`;
//! * `items(sku, price, subcategory, category, category_cluster)` — with
//!   the FD-chain `sku → subcategory → category`.
//!
//! Like the real dataset, `|X|` has the same *rows* as the fact table but
//! ~3× the columns, so materialization blows up bytes, not rows.

use crate::data::{Attr, Database, Relation, Schema, Value};
use crate::query::Feq;
use crate::util::{SplitMix64, Zipf};

use super::Scale;

/// Dimension sizes derived from the scale factor.
struct Dims {
    stores: usize,
    zips: usize,
    cities: usize,
    states: usize,
    dates: usize,
    skus: usize,
    subcats: usize,
    cats: usize,
    clusters: usize,
    fact_rows: usize,
}

fn dims(scale: Scale) -> Dims {
    let stores = scale.n(200, 8);
    let zips = (stores / 3).max(4);
    let cities = (zips / 3).max(3);
    let states = (cities / 4).max(2);
    let skus = scale.n(5000, 40);
    let subcats = (skus / 20).max(12);
    let cats = (subcats / 4).max(6);
    Dims {
        stores,
        zips,
        cities,
        states,
        dates: scale.n(364, 20),
        skus,
        subcats,
        cats,
        clusters: 8,
        fact_rows: scale.n(2_000_000, 400),
    }
}

/// Generate the Retailer database at a scale.
pub fn generate(scale: Scale, seed: u64) -> Database {
    let d = dims(scale);
    let mut rng = SplitMix64::new(seed ^ 0x5e7a11e5);
    let mut db = Database::new();

    // location: store -> zip -> city -> state FD chain.
    let mut location = Relation::new(
        "location",
        Schema::new(vec![
            Attr::cat("store", d.stores as u32),
            Attr::cat("zip", d.zips as u32),
            Attr::cat("city", d.cities as u32),
            Attr::cat("state", d.states as u32),
            Attr::double("distance_comp"),
            Attr::cat("store_type", 5),
        ]),
    );
    let zip_of: Vec<u32> = (0..d.stores).map(|_| rng.below(d.zips as u64) as u32).collect();
    let city_of: Vec<u32> = (0..d.zips).map(|_| rng.below(d.cities as u64) as u32).collect();
    let state_of: Vec<u32> = (0..d.cities).map(|_| rng.below(d.states as u64) as u32).collect();
    for s in 0..d.stores {
        let zip = zip_of[s];
        location.push_row(&[
            Value::Cat(s as u32),
            Value::Cat(zip),
            Value::Cat(city_of[zip as usize]),
            Value::Cat(state_of[city_of[zip as usize] as usize]),
            Value::Double((rng.uniform(0.1, 40.0) * 10.0).round() / 10.0),
            Value::Cat(rng.below(5) as u32),
        ]);
    }
    db.add(location);
    db.add_fd("store", "zip");
    db.add_fd("zip", "city");
    db.add_fd("city", "state");

    // census: one row per zip, a few demographic doubles.
    let mut census = Relation::new(
        "census",
        Schema::new(vec![
            Attr::cat("zip", d.zips as u32),
            Attr::double("population"),
            Attr::double("income"),
            Attr::double("median_age"),
            Attr::double("house_units"),
        ]),
    );
    for z in 0..d.zips {
        census.push_row(&[
            Value::Cat(z as u32),
            Value::Double((rng.uniform(1.0, 80.0) * 1000.0).round()),
            Value::Double((rng.uniform(25.0, 150.0) * 1000.0).round()),
            Value::Double(rng.uniform(24.0, 55.0).round()),
            Value::Double((rng.uniform(0.4, 30.0) * 1000.0).round()),
        ]);
    }
    db.add(census);

    // weather: full store × date grid, coarse-grained doubles.
    let mut weather = Relation::new(
        "weather",
        Schema::new(vec![
            Attr::cat("store", d.stores as u32),
            Attr::cat("date", d.dates as u32),
            Attr::double("temp"),
            Attr::cat("rain", 2),
        ]),
    );
    for s in 0..d.stores {
        for t in 0..d.dates {
            // Seasonal temperature, rounded to whole degrees.
            let season = (t as f64 / d.dates.max(1) as f64 * std::f64::consts::TAU).sin();
            weather.push_row(&[
                Value::Cat(s as u32),
                Value::Cat(t as u32),
                Value::Double((15.0 + 12.0 * season + 3.0 * rng.normal()).round()),
                Value::Cat(u32::from(rng.coin(0.25))),
            ]);
        }
    }
    db.add(weather);

    // items: sku -> subcategory -> category FD chain + price.
    let mut items = Relation::new(
        "items",
        Schema::new(vec![
            Attr::cat("sku", d.skus as u32),
            Attr::double("price"),
            Attr::cat("subcategory", d.subcats as u32),
            Attr::cat("category", d.cats as u32),
            Attr::cat("category_cluster", d.clusters as u32),
        ]),
    );
    let subcat_of: Vec<u32> = (0..d.skus).map(|_| rng.below(d.subcats as u64) as u32).collect();
    let cat_of: Vec<u32> = (0..d.subcats).map(|_| rng.below(d.cats as u64) as u32).collect();
    let cluster_of: Vec<u32> = (0..d.cats).map(|_| rng.below(d.clusters as u64) as u32).collect();
    for sku in 0..d.skus {
        let sc = subcat_of[sku];
        let c = cat_of[sc as usize];
        items.push_row(&[
            Value::Cat(sku as u32),
            Value::Double((rng.uniform(0.5, 120.0) * 100.0).round() / 100.0),
            Value::Cat(sc),
            Value::Cat(c),
            Value::Cat(cluster_of[c as usize]),
        ]);
    }
    db.add(items);
    db.add_fd("sku", "subcategory");
    db.add_fd("subcategory", "category");
    db.add_fd("category", "category_cluster");

    // inventory: the Zipf-skewed fact table.
    let mut inventory = Relation::new(
        "inventory",
        Schema::new(vec![
            Attr::cat("store", d.stores as u32),
            Attr::cat("date", d.dates as u32),
            Attr::cat("sku", d.skus as u32),
            Attr::double("units"),
        ]),
    );
    let sku_zipf = Zipf::new(d.skus, 1.1);
    for _ in 0..d.fact_rows {
        let sku = sku_zipf.sample(&mut rng);
        // Popular skus carry more units; integers like real inventory.
        let base = 40.0 / (1.0 + sku as f64).sqrt();
        inventory.push_row(&[
            Value::Cat(rng.below(d.stores as u64) as u32),
            Value::Cat(rng.below(d.dates as u64) as u32),
            Value::Cat(sku as u32),
            Value::Double((base * rng.uniform(0.2, 2.0)).round().max(0.0)),
        ]);
    }
    db.add(inventory);

    db
}

/// The Retailer FEQ: join all five relations; cluster on the paper-style
/// feature set (ids like `sku`/`store`/`date` are join keys, not features
/// — matching the paper's modest one-hot width of 95).
pub fn feq() -> Feq {
    Feq::with_features(
        &["inventory", "location", "census", "weather", "items"],
        &[
            "units",
            "price",
            "subcategory",
            "category",
            "category_cluster",
            "zip",
            "city",
            "state",
            "store_type",
            "distance_comp",
            "population",
            "income",
            "median_age",
            "house_units",
            "temp",
            "rain",
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faq::output_size;
    use crate::query::Hypergraph;

    #[test]
    fn join_preserves_fact_rows() {
        // Every inventory row joins exactly one row in each dimension, so
        // |X| = |inventory| (the paper's Retailer shape).
        let db = generate(Scale::tiny(), 1);
        let feq = feq();
        let tree = Hypergraph::from_feq(&db, &feq).join_tree().unwrap();
        let x = output_size(&db, &tree).unwrap();
        assert_eq!(x, db.get("inventory").unwrap().n_rows() as f64);
    }

    #[test]
    fn fd_chain_is_present() {
        let db = generate(Scale::tiny(), 2);
        let chains = db.fd_chains(&[
            "zip".to_string(),
            "city".to_string(),
            "state".to_string(),
            "temp".to_string(),
        ]);
        assert!(chains
            .iter()
            .any(|c| c == &["zip".to_string(), "city".to_string(), "state".to_string()]));
    }

    #[test]
    fn zipf_skew_exists() {
        let db = generate(Scale::tiny(), 3);
        let inv = db.get("inventory").unwrap();
        let sku_col = inv.schema.index_of("sku").unwrap();
        let mut counts = std::collections::HashMap::new();
        for r in 0..inv.n_rows() {
            *counts.entry(inv.col(sku_col).key_u64(r)).or_insert(0usize) += 1;
        }
        let max = counts.values().max().copied().unwrap_or(0);
        let avg = inv.n_rows() / counts.len().max(1);
        assert!(max > 3 * avg, "head sku {max} should dominate average {avg}");
    }
}
