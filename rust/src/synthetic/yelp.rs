//! Synthetic **Yelp** (paper §5: 5 relations, 25 attrs, 1617 one-hot; the
//! public Yelp Dataset Challenge [46]).
//!
//! The distinguishing structural feature: `category(business, category)` is
//! **many-to-many** — a business has several categories — so the join
//! output is a multiple of the review count (`8.7M` base rows → `22M`
//! join rows in the paper). That blowup (`|X| ≫ |D|`) is what the
//! generator reproduces.
//!
//! Schema:
//! * `review(user, business, stars, rev_age)` — fact table, Zipf over both
//!   users and businesses;
//! * `users(user, review_count, fans, avg_stars)`;
//! * `business(business, city, state, b_stars, b_review_count)`;
//! * `category(business, category)` — ~2.5 rows per business;
//! * `attributes(business, n_attributes)`.

use crate::data::{Attr, Database, Relation, Schema, Value};
use crate::query::Feq;
use crate::util::{SplitMix64, Zipf};

use super::Scale;

struct Dims {
    users: usize,
    businesses: usize,
    categories: usize,
    cities: usize,
    states: usize,
    reviews: usize,
}

fn dims(scale: Scale) -> Dims {
    let businesses = scale.n(10_000, 60);
    Dims {
        users: scale.n(50_000, 120),
        businesses,
        categories: scale.n(300, 15),
        cities: (businesses / 100).max(8),
        states: 12,
        reviews: scale.n(1_000_000, 400),
    }
}

/// Generate the Yelp database at a scale.
pub fn generate(scale: Scale, seed: u64) -> Database {
    let d = dims(scale);
    let mut rng = SplitMix64::new(seed ^ 0x1e1f_ca75);
    let mut db = Database::new();

    // users
    let mut users = Relation::new(
        "users",
        Schema::new(vec![
            Attr::cat("user", d.users as u32),
            Attr::double("review_count"),
            Attr::double("fans"),
            Attr::double("avg_stars"),
        ]),
    );
    for u in 0..d.users {
        let rc = (1.0 + rng.uniform(0.0, 3.0).exp2()).round();
        users.push_row(&[
            Value::Cat(u as u32),
            Value::Double(rc),
            Value::Double((rc * rng.uniform(0.0, 0.3)).round()),
            Value::Double((rng.uniform(1.0, 5.0) * 2.0).round() / 2.0),
        ]);
    }
    db.add(users);

    // business
    let mut business = Relation::new(
        "business",
        Schema::new(vec![
            Attr::cat("business", d.businesses as u32),
            Attr::cat("city", d.cities as u32),
            Attr::cat("state", d.states as u32),
            Attr::double("b_stars"),
            Attr::double("b_review_count"),
        ]),
    );
    let state_of: Vec<u32> = (0..d.cities).map(|_| rng.below(d.states as u64) as u32).collect();
    for b in 0..d.businesses {
        let city = rng.below(d.cities as u64) as u32;
        business.push_row(&[
            Value::Cat(b as u32),
            Value::Cat(city),
            Value::Cat(state_of[city as usize]),
            Value::Double((rng.uniform(1.0, 5.0) * 2.0).round() / 2.0),
            Value::Double(rng.uniform(0.0, 4.0).exp2().round()),
        ]);
    }
    db.add(business);
    db.add_fd("city", "state");

    // category: many-to-many — the join-blowup source. Each business gets
    // 1 + Geometric-ish extra categories (mean ≈ 2.5).
    let mut category = Relation::new(
        "category",
        Schema::new(vec![
            Attr::cat("business", d.businesses as u32),
            Attr::cat("category", d.categories as u32),
        ]),
    );
    let cat_zipf = Zipf::new(d.categories, 0.9);
    for b in 0..d.businesses {
        let n_cats = 1 + (rng.below(4) + rng.below(2)) as usize; // 1..=5, mean 2.5
        let mut seen = Vec::with_capacity(n_cats);
        for _ in 0..n_cats {
            let c = cat_zipf.sample(&mut rng) as u32;
            if !seen.contains(&c) {
                seen.push(c);
                category.push_row(&[Value::Cat(b as u32), Value::Cat(c)]);
            }
        }
    }
    db.add(category);

    // attributes: aggregated attribute count per business.
    let mut attributes = Relation::new(
        "attributes",
        Schema::new(vec![
            Attr::cat("business", d.businesses as u32),
            Attr::double("n_attributes"),
        ]),
    );
    for b in 0..d.businesses {
        attributes.push_row(&[Value::Cat(b as u32), Value::Double(rng.below(30) as f64)]);
    }
    db.add(attributes);

    // review: the fact table.
    let mut review = Relation::new(
        "review",
        Schema::new(vec![
            Attr::cat("user", d.users as u32),
            Attr::cat("business", d.businesses as u32),
            Attr::double("stars"),
            Attr::double("rev_age"),
        ]),
    );
    let user_zipf = Zipf::new(d.users, 1.1);
    let biz_zipf = Zipf::new(d.businesses, 1.05);
    for _ in 0..d.reviews {
        review.push_row(&[
            Value::Cat(user_zipf.sample(&mut rng) as u32),
            Value::Cat(biz_zipf.sample(&mut rng) as u32),
            Value::Double(1.0 + rng.below(5) as f64),
            Value::Double(rng.below(3000) as f64),
        ]);
    }
    db.add(review);

    db
}

/// The Yelp FEQ. `category` is a feature *and* the m:n blowup source.
pub fn feq() -> Feq {
    Feq::with_features(
        &["review", "users", "business", "category", "attributes"],
        &[
            "stars",
            "rev_age",
            "review_count",
            "fans",
            "avg_stars",
            "city",
            "state",
            "b_stars",
            "b_review_count",
            "category",
            "n_attributes",
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faq::output_size;
    use crate::query::Hypergraph;

    #[test]
    fn join_blows_up_reviews() {
        // |X| must exceed |review| — the m:n category join at work.
        let db = generate(Scale::tiny(), 1);
        let tree = Hypergraph::from_feq(&db, &feq()).join_tree().unwrap();
        let x = output_size(&db, &tree).unwrap();
        let reviews = db.get("review").unwrap().n_rows() as f64;
        assert!(x > 1.5 * reviews, "|X| = {x} vs reviews {reviews}");
        assert!(x < 6.0 * reviews, "|X| = {x} suspiciously large");
    }

    #[test]
    fn categories_are_multivalued() {
        let db = generate(Scale::tiny(), 2);
        let cat = db.get("category").unwrap();
        let biz = db.get("business").unwrap();
        assert!(cat.n_rows() > biz.n_rows(), "avg categories per business > 1");
    }
}
