//! Columnar relational storage: values, schemas, relations, databases and a
//! CSV import/export path.
//!
//! Attributes come in three types mirroring the paper's feature model:
//! * [`AttrType::Int`] — integer-valued join keys / discrete features,
//! * [`AttrType::Double`] — continuous features (never join keys),
//! * [`AttrType::Cat`] — dictionary-encoded categorical features, which the
//!   paper one-hot encodes into a *categorical subspace* (§4.1).
//!
//! Join keys are encoded as `u64` ([`Value::key_u64`]) so the FAQ engine can
//! hash tuples without touching floats.

pub mod csv;
pub mod database;
pub mod relation;
pub mod schema;
pub mod value;

pub use database::{Database, Fd};
pub use relation::{Column, Relation};
pub use schema::{Attr, AttrType, Schema};
pub use value::{CatId, Value};
