//! Tier-1 gate for `rklint`, the in-tree static-analysis pass.
//!
//! Two halves:
//!
//! 1. **The gate itself** — lint the real `src/` tree and fail the build
//!    on any active (non-waivered) diagnostic. This is what keeps the
//!    determinism contract (`lib.rs` docs) enforced rather than
//!    aspirational.
//! 2. **Rule efficacy** — seed each rule with a synthetic violation and
//!    prove it fires, so a regression in the scanner can't silently
//!    turn the gate into a no-op.

use rkmeans::analysis::{lint_source, lint_tree};
use std::path::Path;

fn src_root() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("src")
}

#[test]
fn source_tree_is_lint_clean() {
    let report = lint_tree(&src_root()).expect("walk src tree");
    assert!(report.files > 0, "gate must actually scan files");
    let active: Vec<String> = report
        .active()
        .map(|d| format!("{}:{} [{}] {}", d.file, d.line, d.rule, d.message))
        .collect();
    assert!(
        active.is_empty(),
        "rklint found {} active diagnostic(s) in src/ — fix the site or add a \
         reasoned waiver:\n{}",
        active.len(),
        active.join("\n")
    );
}

#[test]
fn every_waiver_in_the_tree_carries_a_reason() {
    // `lint_tree` turns reasonless/unknown-rule waivers into active
    // `invalid-waiver` diagnostics, so the clean-tree gate already
    // covers this; the assertion here documents the invariant directly.
    let report = lint_tree(&src_root()).expect("walk src tree");
    assert!(
        !report.diagnostics.iter().any(|d| d.rule == "invalid-waiver"),
        "waiver hygiene regression"
    );
    // And the tree genuinely uses waivers (the registry isn't dead code).
    assert!(report.waived() > 0, "expected at least one reasoned waiver in src/");
}

// ---- rule efficacy: each rule fires on a seeded violation ------------

fn rules_fired(rel_path: &str, src: &str) -> Vec<&'static str> {
    let mut rules: Vec<&'static str> =
        lint_source(rel_path, src).into_iter().filter(|d| !d.waived).map(|d| d.rule).collect();
    rules.sort_unstable();
    rules.dedup();
    rules
}

#[test]
fn rogue_thread_fires_outside_the_registry() {
    let src = "fn sneak() { std::thread::spawn(|| {}); }\n";
    assert_eq!(rules_fired("src/cluster/sneaky.rs", src), ["rogue-thread"]);
}

#[test]
fn nondet_iteration_fires_on_unsorted_hashmap_walks() {
    let src = "use rustc_hash::FxHashMap;\n\
               fn leak(m: &FxHashMap<u64, f64>) -> Vec<u64> {\n\
                   let mut out = Vec::new();\n\
                   for (k, _) in m.iter() { out.push(*k); }\n\
                   out\n\
               }\n";
    assert_eq!(rules_fired("src/faq/sneaky.rs", src), ["nondet-iteration"]);
}

#[test]
fn wall_clock_fires_outside_telemetry_modules() {
    let src = "fn tick() -> std::time::Instant { std::time::Instant::now() }\n";
    assert_eq!(rules_fired("src/rkmeans/sneaky.rs", src), ["wall-clock-in-core"]);
    // …but not inside the telemetry allowlist.
    assert_eq!(rules_fired("src/metrics/sneaky.rs", src), [] as [&str; 0]);
}

#[test]
fn unchecked_cast_fires_in_wire_files_only() {
    let src = "fn enc(n: usize) -> f64 { n as f64 }\n";
    assert_eq!(rules_fired("src/rkmeans/model.rs", src), ["unchecked-cast-in-wire"]);
    assert_eq!(rules_fired("src/serve/rpc/wire.rs", src), ["unchecked-cast-in-wire"]);
    assert_eq!(rules_fired("src/rkmeans/pipeline.rs", src), [] as [&str; 0]);
}

#[test]
fn rpc_spawn_sites_are_registered_but_strays_are_not() {
    // The three registered socket-tier spawn fns are waived…
    let registered = "fn accept_loop() { std::thread::Builder::new(); }\n";
    assert_eq!(rules_fired("src/serve/rpc/mod.rs", registered), [] as [&str; 0]);
    // …while a spawn in any other fn of the same file still fires.
    let stray = "fn helper() { std::thread::spawn(|| {}); }\n";
    assert_eq!(rules_fired("src/serve/rpc/mod.rs", stray), ["rogue-thread"]);
}

#[test]
fn contextless_unwrap_fires_on_lock_results_in_serve() {
    let src = "fn peek(m: &std::sync::Mutex<u64>) -> u64 { *m.lock().unwrap() }\n";
    assert_eq!(rules_fired("src/serve/sneaky.rs", src), ["contextless-unwrap"]);
    // Outside the gated paths the same code is allowed.
    assert_eq!(rules_fired("src/faq/sneaky.rs", src), [] as [&str; 0]);
}

#[test]
fn unbounded_channel_fires_outside_the_queue_registry() {
    // Bare `channel()` — unbounded, no backpressure.
    let src = "fn sneak() { let (tx, rx) = std::sync::mpsc::channel(); tx.send(1).ok(); }\n";
    assert_eq!(rules_fired("src/cluster/sneaky.rs", src), ["unbounded-channel"]);
    // Turbofish form is the same construction site.
    let fish = "fn sneak() { let (tx, rx) = std::sync::mpsc::channel::<Vec<u64>>(); }\n";
    assert_eq!(rules_fired("src/cluster/sneaky.rs", fish), ["unbounded-channel"]);
    // Zero-capacity rendezvous defeats the try_send backpressure pattern.
    let zero = "fn sneak() { let (tx, rx) = std::sync::mpsc::sync_channel::<u64>(0); }\n";
    assert_eq!(rules_fired("src/cluster/sneaky.rs", zero), ["unbounded-channel"]);
}

#[test]
fn bounded_sync_channel_is_the_pattern_not_a_finding() {
    let src = "fn fine() { let (tx, rx) = std::sync::mpsc::sync_channel::<u64>(16); }\n";
    assert_eq!(rules_fired("src/cluster/fine.rs", src), [] as [&str; 0]);
}

#[test]
fn queue_registry_sites_are_waived_but_strays_in_the_same_file_fire() {
    // The registered front fns carry the registry reason…
    let registered = "fn submit() { let (rtx, rrx) = std::sync::mpsc::channel(); }\n";
    let diags = lint_source("src/serve/front.rs", registered);
    assert!(
        diags.iter().any(|d| {
            d.rule == "unbounded-channel"
                && d.waived
                && d.waiver_reason.as_deref().is_some_and(|r| r.starts_with("registry:"))
        }),
        "registered queue must surface as a waived diagnostic: {diags:?}"
    );
    assert!(diags.iter().all(|d| d.waived));
    // …while the same construction in an unregistered fn still fires.
    let stray = "fn helper() { let (tx, rx) = std::sync::mpsc::channel(); }\n";
    assert_eq!(rules_fired("src/serve/front.rs", stray), ["unbounded-channel"]);
}

// ---- waiver mechanics ------------------------------------------------

#[test]
fn reasoned_waiver_suppresses_and_reasonless_does_not() {
    let reasoned = "// rklint::allow(wall-clock-in-core, reason = \"seeded fixture\")\n\
                    fn tick() -> std::time::Instant { std::time::Instant::now() }\n";
    let diags = lint_source("src/rkmeans/sneaky.rs", reasoned);
    assert!(diags.iter().all(|d| d.waived), "reasoned waiver must suppress: {diags:?}");
    assert_eq!(diags.iter().filter(|d| d.waived).count(), 1);

    let reasonless = "// rklint::allow(wall-clock-in-core)\n\
                      fn tick() -> std::time::Instant { std::time::Instant::now() }\n";
    let fired = rules_fired("src/rkmeans/sneaky.rs", reasonless);
    assert!(
        fired.contains(&"wall-clock-in-core") && fired.contains(&"invalid-waiver"),
        "reasonless waiver must not suppress and must itself be flagged: {fired:?}"
    );
}

#[test]
fn unknown_rule_waiver_is_flagged() {
    let src = "// rklint::allow(no-such-rule, reason = \"typo\")\nfn f() {}\n";
    assert_eq!(rules_fired("src/rkmeans/sneaky.rs", src), ["invalid-waiver"]);
}
