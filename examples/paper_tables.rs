//! Regenerate every table and figure from the paper's evaluation (§5).
//!
//! ```sh
//! # everything at the default scale:
//! cargo run --release --offline --example paper_tables
//! # one artifact, custom scale:
//! cargo run --release --offline --example paper_tables -- --which table2 --scale 0.1
//! ```
//!
//! Output is markdown (paste-ready for EXPERIMENTS.md). See DESIGN.md for
//! the experiment index mapping each artifact to its modules.

use rkmeans::bench_harness::paper::{self, PaperCfg};
use rkmeans::synthetic::Dataset;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let get = |flag: &str| -> Option<String> {
        args.iter().position(|a| a == flag).and_then(|i| args.get(i + 1).cloned())
    };
    let scale: f64 = get("--scale")
        .and_then(|s| s.parse().ok())
        .or_else(|| std::env::var("RKMEANS_SCALE").ok().and_then(|s| s.parse().ok()))
        .unwrap_or(0.02);
    let which = get("--which").unwrap_or_else(|| "all".to_string());
    let mut cfg = PaperCfg::new(scale);
    if args.iter().any(|a| a == "--no-approx") {
        cfg.eval_approx = false;
    }
    let all = which == "all";

    if all || which == "table1" {
        println!("{}", paper::table1(&cfg)?.render());
    }
    if all || which == "table2" {
        for ds in Dataset::all() {
            println!("{}", paper::table2(ds, &cfg)?.render());
        }
    }
    if all || which == "fig3" {
        for ds in Dataset::all() {
            println!("{}", paper::fig3(ds, &cfg)?.render());
        }
    }
    if all || which == "ablation-fd" {
        println!("{}", paper::ablation_fd(&cfg)?.render());
    }
    if all || which == "ablation-sparse" {
        for ds in Dataset::all() {
            println!("{}", paper::ablation_sparse(ds, 10, &cfg)?.render());
        }
    }
    if all || which == "kappa-sweep" {
        println!(
            "{}",
            paper::kappa_sweep(Dataset::Favorita, 20, &[2, 5, 10, 20], &cfg)?.render()
        );
    }
    Ok(())
}
