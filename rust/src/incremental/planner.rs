//! The patch-vs-rebuild planner: per delta batch, decide between the
//! cheap path (Step-3 delta + Step-4 warm start) and the full pipeline.
//!
//! **Patch** keeps the Step-2 models (and hence gid maps) frozen, feeds
//! the batch through [`DeltaLayer::apply`] — one [`DeltaFaq`](super::DeltaFaq)
//! over the whole database, or per-shard instances patched in parallel
//! and merged at the root when [`PlannerOpts::shards`] > 1 (see
//! [`super::sharded`]) — converts the patched grid with
//! [`crate::coreset::sparse_from_table`], and re-clusters with
//! [`crate::rkmeans::Coreset::cluster_resume`]: seeded from the previous
//! version's centroids **and** resumed from the carried Step-4
//! [`EngineState`] (final assignments + bounds, spliced across the grid
//! edit via [`DeltaLayer::last_splices`]), so the warm-started Lloyd skips
//! the full first assignment scan — per-batch Step-4 cost is
//! `O(b + changed cells)`, bitwise-identical to the cold warm start.
//! Steps 1 and 2 are skipped entirely, which is where the
//! `Õ(|D|)`-per-batch cost of the recompute loop goes away. When a
//! batch's tombstone ratio passes [`PlannerOpts::compact_ratio`], the
//! retained Step-3 messages are compacted in place
//! ([`DeltaLayer::compact`]) to bound delete-heavy resident memory.
//!
//! **Rebuild** is the existing full pipeline
//! ([`crate::rkmeans::rkmeans_with_tree`]) followed by re-initializing the
//! delta state and re-baselining the marginal sketches. It triggers when:
//! * a marginal sketch drifts past [`PlannerOpts::drift_threshold`]
//!   (frozen Step-2 models have gone stale),
//! * the batch exceeds [`PlannerOpts::max_patch_fraction`]·|D| (the delta
//!   pass would touch most of the tree anyway),
//! * [`PlannerOpts::rebuild_every`] batches have been patched in a row
//!   (bounds FP drift on non-integer weights),
//! * cumulative join-level churn (Σ|Δweight| over patched cells, an
//!   exact byproduct of the Step-3 delta) passes
//!   [`PlannerOpts::max_join_churn`]·mass — the backstop for join-key
//!   fanout drift the base-table sketches cannot see, or
//! * the patch itself fails (e.g. the ℤ-ring invariant is violated).
//!
//! Every decision and its cost is recorded in [`Metrics`]
//! (`incremental.*`), including an estimated per-batch saving against the
//! last observed rebuild time.

use crate::cluster::{CentroidCoord, EngineState};
use crate::coreset::{sparse_from_table, SubspaceModel};
use crate::data::Database;
use crate::faq::GidAssigner;
use crate::metrics::Metrics;
use crate::query::{Feq, Hypergraph, JoinTree};
use crate::rkmeans::{
    ClusterOpts, Coreset, RkConfig, RkModel, RkPipeline, RkResult, StepTimings, SubspaceOpts,
};
use crate::util::FxHashMap;
use anyhow::{bail, Context, Result};
use std::sync::Arc;

use super::{DeltaLayer, MarginalTracker, TupleDelta};

/// Planner thresholds.
#[derive(Clone, Debug)]
pub struct PlannerOpts {
    /// Rebuild when any feature's marginal sketch drifts past this
    /// (TV distance for categorical, range-normalized W₁ for continuous).
    pub drift_threshold: f64,
    /// Rebuild when `|batch| > max_patch_fraction · |D|`.
    pub max_patch_fraction: f64,
    /// Force a rebuild after this many consecutive patches (0 = never).
    pub rebuild_every: usize,
    /// Rebuild when the cumulative join-level churn since the last
    /// rebuild — Σ|Δweight| over patched grid cells, reported exactly by
    /// the Step-3 delta — exceeds this fraction of the grid mass. This
    /// backstops the base-table sketches, which cannot see join-*key*
    /// fanout shifts (see [`super::marginal`]).
    pub max_join_churn: f64,
    /// Carry the Step-4 [`EngineState`] (assignments + bounds) across
    /// batches: each patch splices the state over the grid edit and
    /// resumes, so the warm-started Lloyd skips the full first scan and
    /// per-batch Step-4 cost is `O(b + changed cells)`. Bitwise-identical
    /// to the cold warm start (`false` = the pre-carry behavior, kept as
    /// the bench ablation arm).
    pub carry_state: bool,
    /// Compact the retained Step-3 state
    /// ([`DeltaLayer::compact`]) when its tombstone ratio exceeds this
    /// (removed entries / live entries; `f64::INFINITY` = never). Bounds
    /// delete-heavy resident memory at the cost of an occasional
    /// `Õ(|D|)` message rebuild.
    pub compact_ratio: f64,
    /// Horizontal shard count for the Step-3 state (`<= 1` = unsharded).
    /// `> 1` hash-partitions the fact relation ([`crate::faq::shard`]):
    /// rebuilds run the grid pass per shard on the shared worker pool
    /// ([`crate::rkmeans::RkPipeline::coreset_sharded`]) and patches
    /// apply per-shard [`super::DeltaFaq`] batches in parallel, merged at
    /// the root ([`super::ShardedDeltaFaq`]). Ring-ℤ exact: on
    /// integer-weighted databases every published result is bitwise
    /// identical to the unsharded planner's.
    pub shards: usize,
    /// Learn the patch-vs-rebuild crossover from observed latencies (cost
    /// model v1): exponentially-weighted per-delta patch cost and rebuild
    /// cost estimates replace the static `max_patch_fraction` size check
    /// once both paths have been observed — rebuild when the predicted
    /// patch cost strictly exceeds the predicted rebuild cost (ties
    /// deterministically patch). Quality triggers (drift, churn,
    /// schedule) stay active; they guard correctness, not cost.
    pub cost_model: bool,
    /// Cold-key spill budget for the retained Step-3 messages: maximum
    /// resident non-root separator-key tables per [`super::DeltaFaq`]
    /// state (per shard on the sharded path); colder keys spill to disk
    /// and reload on touch ([`super::DeltaFaq::set_spill_budget`]).
    /// 0 disables spilling.
    pub spill_budget: usize,
}

impl Default for PlannerOpts {
    fn default() -> Self {
        PlannerOpts {
            drift_threshold: 0.15,
            max_patch_fraction: 0.05,
            rebuild_every: 0,
            max_join_churn: 0.5,
            carry_state: true,
            compact_ratio: 0.5,
            shards: 1,
            cost_model: false,
            spill_budget: 0,
        }
    }
}

/// Why a batch was (or was not) patched.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PlanDecision {
    /// Step-3 delta + Step-4 warm start.
    Patched,
    /// Full pipeline rebuild, and why.
    Rebuilt(RebuildReason),
}

/// Rebuild triggers (see module docs).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RebuildReason {
    /// First build of the engine.
    Init,
    /// A marginal sketch drifted past the threshold (feature name).
    Drift(String),
    /// The batch was too large relative to `|D|`.
    BatchTooLarge,
    /// The periodic `rebuild_every` schedule fired.
    Schedule,
    /// Cumulative join-level churn passed `max_join_churn`·mass.
    JoinChurn,
    /// The learned cost model predicted the patch would cost more than a
    /// rebuild ([`PlannerOpts::cost_model`]).
    CostModel,
    /// The patch path failed (error text); state was re-initialized.
    PatchFailed(String),
}

/// One closed ingest epoch, as the multi-producer tier hands it to
/// [`IncrementalEngine::apply_epoch`]: the merged grid at the epoch
/// boundary, the composed splice log against the previously published
/// grid, the epoch's logical single-stream delta sequence (for the
/// marginal tracker and the rebuild triggers), and the aggregated patch
/// stats. Built by [`crate::ingest::IngestHub`].
#[derive(Clone, Debug)]
pub struct EpochPatch {
    /// The closed epoch number.
    pub epoch: u64,
    /// The epoch's deltas in canonical (serial-equivalent) order.
    pub deltas: Vec<TupleDelta>,
    /// Merged sorted grid snapshot at the epoch boundary.
    pub table: crate::faq::GridTable,
    /// Structural edits vs the previous epoch's merged snapshot.
    pub splices: Vec<crate::cluster::StateSplice>,
    /// Aggregated Step-3 stats of the epoch.
    pub stats: super::PatchStats,
}

/// Snapshot of everything the serving layer needs to answer queries at a
/// version — and everything the engine needs to keep patching from it.
/// Cloneable, so snapshots taken while patches continue stay consistent;
/// [`IncrementalEngine::restore`] rolls the engine back to one.
#[derive(Clone)]
pub struct IncrementalState {
    /// Monotonically increasing state version (bumped per batch).
    pub version: u64,
    /// Frozen Step-2 models (gid maps stable across patches).
    pub models: Vec<SubspaceModel>,
    /// Persistent Step-3 message state (per-shard with merged root when
    /// [`PlannerOpts::shards`] > 1).
    pub delta: DeltaLayer,
    /// Marginal sketches + baselines for the drift trigger.
    pub tracker: MarginalTracker,
    /// Step-4 centroids of this version (the warm start for the next).
    pub centroids: Vec<Vec<CentroidCoord>>,
    /// Carried Step-4 engine state (final assignments + bounds, tagged
    /// with the centroid hash): spliced across each batch's grid edit and
    /// resumed so the next patch skips the full first scan. `None` only
    /// before the first Step-4 run of a restored legacy snapshot.
    pub engine_state: Option<EngineState>,
    /// The clustering result published at this version (shared: handed
    /// out per batch without deep-copying models/centroids).
    pub result: Arc<RkResult>,
}

impl IncrementalState {
    /// A self-contained serving model of this version: factored centroids
    /// + subspace assigners, **without** the delta messages — the
    /// snapshot-shipping payload. Serialize with
    /// [`RkModel::to_bytes`] and replicas serve this version (tagged via
    /// [`RkModel::version`]) while the writer keeps patching.
    pub fn model(&self) -> RkModel {
        RkModel::from_result(&self.result).with_version(self.version)
    }
}

/// The incremental maintenance engine the coordinator drives (see module
/// docs for the decision procedure).
pub struct IncrementalEngine {
    feq: Feq,
    tree: JoinTree,
    rk: RkConfig,
    opts: PlannerOpts,
    metrics: Metrics,
    state: IncrementalState,
    patches_since_rebuild: usize,
    /// Σ|Δweight| over patched grid cells since the last rebuild.
    join_churn: f64,
    /// Seconds of the last observed rebuild (savings estimate).
    last_rebuild_s: f64,
    /// Cost model v1: exponentially-weighted per-delta patch seconds,
    /// `None` until the first patch has been observed.
    ew_patch_per_delta_s: Option<f64>,
    /// Exponentially-weighted rebuild seconds, `None` until observed.
    ew_rebuild_s: Option<f64>,
}

/// Exponentially-weighted update (α = 0.3); the first observation seeds
/// the estimate directly.
fn ew_update(prev: Option<f64>, obs: f64) -> Option<f64> {
    const ALPHA: f64 = 0.3;
    Some(match prev {
        Some(p) => p + ALPHA * (obs - p),
        None => obs,
    })
}

/// Borrow a frozen Step-2 model set as the gid-assigner map the FAQ
/// layers consume. The ingest tier builds its shard-local maps from
/// [`IncrementalEngine::models`] through this, which is what keeps the
/// hub's grids bitwise-aligned with the engine's.
pub fn assigner_map(models: &[SubspaceModel]) -> FxHashMap<String, Box<dyn GidAssigner + '_>> {
    let mut m: FxHashMap<String, Box<dyn GidAssigner + '_>> = FxHashMap::default();
    for model in models {
        m.insert(model.name.clone(), Box::new(model));
    }
    m
}

impl IncrementalEngine {
    /// Build the engine with an initial full rebuild. Fails when the FEQ
    /// is invalid or cyclic (the caller then falls back to the
    /// recompute-everything loop).
    pub fn new(
        db: &Database,
        feq: Feq,
        rk: RkConfig,
        opts: PlannerOpts,
        metrics: Metrics,
    ) -> Result<IncrementalEngine> {
        feq.validate(db)?;
        let tree = Hypergraph::from_feq(db, &feq)
            .join_tree()
            .context("incremental maintenance requires an acyclic FEQ")?;
        let (state, elapsed_s) = Self::full_build(db, &feq, &tree, &rk, 0, &opts)?;
        let mut engine = IncrementalEngine {
            feq,
            tree,
            rk,
            opts,
            metrics,
            state,
            patches_since_rebuild: 0,
            join_churn: 0.0,
            last_rebuild_s: elapsed_s,
            ew_patch_per_delta_s: None,
            ew_rebuild_s: None,
        };
        engine.record_rebuild(elapsed_s, &RebuildReason::Init);
        Ok(engine)
    }

    /// Full pipeline + fresh delta/tracker state at `version + 1`.
    fn full_build(
        db: &Database,
        feq: &Feq,
        tree: &JoinTree,
        rk: &RkConfig,
        version: u64,
        opts: &PlannerOpts,
    ) -> Result<(IncrementalState, f64)> {
        let shards = opts.shards;
        let t0 = crate::util::timer::now();
        // Staged pipeline over the caller's tree (bitwise-identical to the
        // monolithic shim; see `crate::rkmeans::pipeline`). Stages are run
        // explicitly so the Step-4 engine state can be captured: the
        // staged coreset and the delta-maintained grid share the same
        // sorted cell order, so the state carries straight into the first
        // patch. With `shards > 1` the Step-3 grid pass runs per shard on
        // the shared pool (bitwise-identical merge).
        let pipe = RkPipeline::with_tree(db, feq, tree);
        let marginals = pipe.marginals()?;
        let subspaces = pipe.subspaces(&marginals, &SubspaceOpts::from_config(rk))?;
        let coreset = pipe.coreset_sharded(&subspaces, shards)?;
        let (model, engine_state) =
            coreset.cluster_resume(&ClusterOpts::from_config(rk), None, None);
        let result = Arc::new(model.into_result());
        let delta = {
            let models = &result.models;
            let mut delta = DeltaLayer::init(db, feq, tree, shards, || assigner_map(models))?;
            delta.set_spill_budget(opts.spill_budget);
            delta
        };
        let tracker = MarginalTracker::new(db, feq)?;
        let state = IncrementalState {
            version: version + 1,
            models: result.models.clone(),
            delta,
            tracker,
            centroids: result.centroids.clone(),
            engine_state: Some(engine_state),
            result,
        };
        Ok((state, t0.elapsed().as_secs_f64()))
    }

    /// Plan and execute one delta batch. `db` must already contain the
    /// batch (inserts pushed, deletes retracted) — the patch path never
    /// reads it, the rebuild path re-derives everything from it.
    pub fn apply_batch(
        &mut self,
        db: &Database,
        deltas: &[TupleDelta],
    ) -> Result<(PlanDecision, Arc<RkResult>)> {
        // Sketches always track the base tables, whatever the decision.
        for d in deltas {
            self.state.tracker.apply(d);
        }

        let reason = self.rebuild_reason(db, deltas);
        let decision = match reason {
            Some(reason) => {
                let elapsed = self.rebuild(db, &reason)?;
                self.record_rebuild(elapsed, &reason);
                PlanDecision::Rebuilt(reason)
            }
            None => match self.try_patch(deltas) {
                Ok(elapsed) => {
                    self.record_patch(elapsed, deltas.len());
                    PlanDecision::Patched
                }
                Err(e) => {
                    // Corrupted or stale delta state: fall back to a
                    // rebuild, which re-initializes it.
                    let reason = RebuildReason::PatchFailed(e.to_string());
                    let elapsed = self.rebuild(db, &reason)?;
                    self.record_rebuild(elapsed, &reason);
                    PlanDecision::Rebuilt(reason)
                }
            },
        };
        Ok((decision, self.state.result.clone()))
    }

    fn rebuild_reason(&self, db: &Database, deltas: &[TupleDelta]) -> Option<RebuildReason> {
        if self.opts.rebuild_every > 0 && self.patches_since_rebuild >= self.opts.rebuild_every {
            return Some(RebuildReason::Schedule);
        }
        // Batch-size economics: the learned crossover once both paths
        // have been observed (rebuild only when the predicted patch cost
        // strictly exceeds the predicted rebuild cost — ties patch, so
        // the decision is deterministic for equal estimates), the static
        // fraction threshold otherwise.
        match (self.opts.cost_model, self.ew_patch_per_delta_s, self.ew_rebuild_s) {
            (true, Some(per_delta), Some(rebuild_s)) => {
                if per_delta * deltas.len() as f64 > rebuild_s {
                    return Some(RebuildReason::CostModel);
                }
            }
            _ => {
                let total = db.total_rows().max(1) as f64;
                if deltas.len() as f64 > self.opts.max_patch_fraction * total {
                    return Some(RebuildReason::BatchTooLarge);
                }
            }
        }
        if self.join_churn > self.opts.max_join_churn * self.state.result.grid_mass.max(1.0) {
            return Some(RebuildReason::JoinChurn);
        }
        let drifted = self.state.tracker.drifted(self.opts.drift_threshold);
        if let Some((name, _)) = drifted.first() {
            return Some(RebuildReason::Drift(name.clone()));
        }
        None
    }

    fn rebuild(&mut self, db: &Database, _reason: &RebuildReason) -> Result<f64> {
        let (state, elapsed) = Self::full_build(
            db,
            &self.feq,
            &self.tree,
            &self.rk,
            self.state.version,
            &self.opts,
        )?;
        self.state = state;
        self.patches_since_rebuild = 0;
        self.join_churn = 0.0;
        self.last_rebuild_s = elapsed;
        Ok(elapsed)
    }

    /// The patch path: Step-3 delta + Step-4 resume (carried assignments
    /// and bounds, spliced over the grid edit). Returns elapsed seconds;
    /// on error the caller rebuilds (the delta state may be poisoned).
    fn try_patch(&mut self, deltas: &[TupleDelta]) -> Result<f64> {
        let t0 = crate::util::timer::now();
        let patch_stats = {
            let models = &self.state.models;
            self.state.delta.apply(deltas, || assigner_map(models))?
        };
        // Keep the carried Step-4 state aligned with the patched grid:
        // replay the batch's structural edits (inserted cells arrive with
        // unbounded rows and get re-scanned; weight-only changes
        // invalidate nothing).
        if let Some(st) = self.state.engine_state.as_mut() {
            st.splice(self.state.delta.last_splices());
        }
        // Delete-heavy memory backstop: rebuild the retained messages
        // tightly once tombstones dominate. On ℤ weights the cell set and
        // order are unchanged so the carried state stays valid; if
        // fractional-weight re-association shifted the cell layout
        // (`compact` returns false) the state is misaligned and dropped.
        self.metrics
            .gauge("incremental.tombstone_pm")
            .set((patch_stats.tombstone_ratio * 1000.0) as i64);
        if patch_stats.tombstone_ratio > self.opts.compact_ratio {
            if !self.state.delta.compact() {
                self.state.engine_state = None;
            }
            self.metrics.counter("incremental.compactions").inc();
        }
        let table = self.state.delta.grid_table();
        let (grid, subspaces) = sparse_from_table(table, &self.state.models);
        if grid.n() == 0 {
            bail!("FEQ output is empty after deltas: nothing to cluster");
        }
        // The delta-patched grid becomes a staged Coreset artifact, so the
        // resumed Step 4 runs through the same code path as the pipeline's
        // `cluster_resume`.
        let coreset = Coreset::from_parts(grid, subspaces, self.state.models.clone());
        let step3 = t0.elapsed();

        let t1 = crate::util::timer::now();
        let carried =
            if self.opts.carry_state { self.state.engine_state.as_ref() } else { None };
        // Count only states `cluster_resume` will actually install (same
        // effective-k/shape filter it applies), so the metric reflects
        // real resumes rather than carry attempts.
        let k_eff = self.rk.k.min(coreset.n()).max(1);
        let resumed = carried
            .map(|st| st.bounds_valid() && st.k() == k_eff && st.n() == coreset.n())
            .unwrap_or(false);
        if resumed {
            self.metrics.counter("incremental.resumes").inc();
        }
        let (model, next_state) = coreset.cluster_resume(
            &ClusterOpts::from_config(&self.rk),
            Some(&self.state.centroids),
            carried,
        );
        let mut model = model.with_version(self.state.version + 1);
        model.timings = StepTimings {
            step3_grid: step3,
            step4_cluster: t1.elapsed(),
            ..StepTimings::default()
        };

        self.state.centroids = model.centroids.clone();
        self.state.engine_state = Some(next_state);
        self.state.version += 1;
        self.state.result = Arc::new(model.into_result());
        self.patches_since_rebuild += 1;
        self.join_churn += patch_stats.mass_delta_abs;
        self.metrics.gauge("incremental.grid_cells").set(patch_stats.grid_cells as i64);
        self.metrics
            .counter("incremental.cells_touched")
            .add(patch_stats.cells_touched as u64);
        self.record_spill_stats();
        Ok(t0.elapsed().as_secs_f64())
    }

    /// Mirror the delta layer's cold-key spill accounting into gauges
    /// (cumulative totals are gauges, not counters — the source already
    /// accumulates).
    fn record_spill_stats(&self) {
        let spill = self.state.delta.spill_stats();
        self.metrics.gauge("incremental.spill_spilled").set(spill.spilled as i64);
        self.metrics.gauge("incremental.spill_reloaded").set(spill.reloaded as i64);
        self.metrics.gauge("incremental.spill_resident").set(spill.resident as i64);
        self.metrics.gauge("incremental.spill_on_disk").set(spill.on_disk as i64);
    }

    /// Plan and execute one closed ingest epoch — the multi-producer
    /// analogue of [`IncrementalEngine::apply_batch`]. The Step-3 work
    /// already happened shard-locally inside the ingest hub, so the patch
    /// path here is tracker upkeep plus the Step-4 resume over the hub's
    /// merged grid and composed splice log. `db` must already mirror the
    /// epoch's deltas. When a quality trigger (drift, churn, schedule) or
    /// the cost model votes rebuild, the full pipeline runs from `db` —
    /// the caller must then rebase the hub onto the rebuilt boundary
    /// (see [`crate::ingest::IngestHub::rebase`]).
    pub fn apply_epoch(
        &mut self,
        db: &Database,
        epoch: &EpochPatch,
    ) -> Result<(PlanDecision, Arc<RkResult>)> {
        for d in &epoch.deltas {
            self.state.tracker.apply(d);
        }
        let reason = self.rebuild_reason(db, &epoch.deltas);
        let decision = match reason {
            Some(reason) => {
                let elapsed = self.rebuild(db, &reason)?;
                self.record_rebuild(elapsed, &reason);
                PlanDecision::Rebuilt(reason)
            }
            None => match self.try_epoch_patch(epoch) {
                Ok(elapsed) => {
                    self.record_patch(elapsed, epoch.deltas.len());
                    PlanDecision::Patched
                }
                Err(e) => {
                    let reason = RebuildReason::PatchFailed(e.to_string());
                    let elapsed = self.rebuild(db, &reason)?;
                    self.record_rebuild(elapsed, &reason);
                    PlanDecision::Rebuilt(reason)
                }
            },
        };
        Ok((decision, self.state.result.clone()))
    }

    /// Step-4 resume over a hub-closed epoch (see
    /// [`IncrementalEngine::apply_epoch`]): splice the carried state over
    /// the epoch's composed edits, rebuild the staged coreset from the
    /// merged grid, resume Lloyd from the previous centroids. Returns
    /// elapsed seconds; on error the caller rebuilds.
    fn try_epoch_patch(&mut self, epoch: &EpochPatch) -> Result<f64> {
        let t0 = crate::util::timer::now();
        if let Some(st) = self.state.engine_state.as_mut() {
            st.splice(&epoch.splices);
        }
        let (grid, subspaces) = sparse_from_table(epoch.table.clone(), &self.state.models);
        if grid.n() == 0 {
            bail!("FEQ output is empty after the epoch: nothing to cluster");
        }
        let coreset = Coreset::from_parts(grid, subspaces, self.state.models.clone());
        let step3 = t0.elapsed();

        let t1 = crate::util::timer::now();
        let carried =
            if self.opts.carry_state { self.state.engine_state.as_ref() } else { None };
        let k_eff = self.rk.k.min(coreset.n()).max(1);
        let resumed = carried
            .map(|st| st.bounds_valid() && st.k() == k_eff && st.n() == coreset.n())
            .unwrap_or(false);
        if resumed {
            self.metrics.counter("incremental.resumes").inc();
        }
        let (model, next_state) = coreset.cluster_resume(
            &ClusterOpts::from_config(&self.rk),
            Some(&self.state.centroids),
            carried,
        );
        let mut model = model.with_version(self.state.version + 1);
        model.timings = StepTimings {
            step3_grid: step3,
            step4_cluster: t1.elapsed(),
            ..StepTimings::default()
        };

        self.state.centroids = model.centroids.clone();
        self.state.engine_state = Some(next_state);
        self.state.version += 1;
        self.state.result = Arc::new(model.into_result());
        self.patches_since_rebuild += 1;
        self.join_churn += epoch.stats.mass_delta_abs;
        self.metrics.gauge("incremental.grid_cells").set(epoch.stats.grid_cells as i64);
        self.metrics.counter("incremental.cells_touched").add(epoch.stats.cells_touched as u64);
        Ok(t0.elapsed().as_secs_f64())
    }

    fn record_patch(&mut self, elapsed_s: f64, n_deltas: usize) {
        self.ew_patch_per_delta_s =
            ew_update(self.ew_patch_per_delta_s, elapsed_s / n_deltas.max(1) as f64);
        self.metrics.counter("incremental.patches").inc();
        self.metrics.counter("incremental.patch_us").add((elapsed_s * 1e6) as u64);
        let saved = (self.last_rebuild_s - elapsed_s).max(0.0);
        self.metrics.counter("incremental.saved_us_est").add((saved * 1e6) as u64);
        self.metrics.gauge("incremental.version").set(self.state.version as i64);
        if let Some(per) = self.ew_patch_per_delta_s {
            self.metrics.gauge("incremental.ew_patch_ns_per_delta").set((per * 1e9) as i64);
        }
    }

    fn record_rebuild(&mut self, elapsed_s: f64, reason: &RebuildReason) {
        self.ew_rebuild_s = ew_update(self.ew_rebuild_s, elapsed_s);
        self.metrics.counter("incremental.rebuilds").inc();
        self.metrics.counter("incremental.rebuild_us").add((elapsed_s * 1e6) as u64);
        let reason_ctr = match reason {
            RebuildReason::Init => "incremental.rebuilds_init",
            RebuildReason::Drift(_) => "incremental.rebuilds_drift",
            RebuildReason::BatchTooLarge => "incremental.rebuilds_batch",
            RebuildReason::Schedule => "incremental.rebuilds_schedule",
            RebuildReason::JoinChurn => "incremental.rebuilds_churn",
            RebuildReason::CostModel => "incremental.rebuilds_cost",
            RebuildReason::PatchFailed(_) => "incremental.rebuilds_patch_failed",
        };
        self.metrics.counter(reason_ctr).inc();
        self.metrics.gauge("incremental.shards").set(self.state.delta.shard_count() as i64);
        self.metrics.gauge("incremental.version").set(self.state.version as i64);
        self.metrics
            .gauge("incremental.ew_rebuild_us")
            .set(self.ew_rebuild_s.map_or(0.0, |s| s * 1e6) as i64);
    }

    /// Seed the cost-model estimates directly (tests force both regimes
    /// without timing-dependent warm-up).
    #[cfg(test)]
    fn seed_cost_estimates(&mut self, patch_per_delta_s: f64, rebuild_s: f64) {
        self.ew_patch_per_delta_s = Some(patch_per_delta_s);
        self.ew_rebuild_s = Some(rebuild_s);
    }

    /// The current state version.
    pub fn version(&self) -> u64 {
        self.state.version
    }

    /// The frozen Step-2 models of the current version. An ingest hub
    /// serving this engine derives its assigner maps from these (via
    /// [`assigner_map`]) so its shard-local grids stay aligned; after a
    /// rebuild the models change and the hub must be rebased
    /// ([`crate::ingest::IngestHub::rebase`]).
    pub fn models(&self) -> &[SubspaceModel] {
        &self.state.models
    }

    /// The clustering result of the current version.
    pub fn result(&self) -> &RkResult {
        &self.state.result
    }

    /// Shared handle to the current result (refcount bump, no deep copy).
    pub fn shared_result(&self) -> Arc<RkResult> {
        self.state.result.clone()
    }

    /// A self-contained serving model of the current version (see
    /// [`IncrementalState::model`]).
    pub fn model(&self) -> RkModel {
        self.state.model()
    }

    /// Snapshot the full maintenance state (serving stays versioned:
    /// consumers can pin a snapshot while patches continue).
    pub fn snapshot(&self) -> IncrementalState {
        self.state.clone()
    }

    /// Roll back to a previously taken snapshot. The caller is
    /// responsible for rolling the database back to the matching point —
    /// subsequent deltas are interpreted against the snapshot's state.
    pub fn restore(&mut self, state: IncrementalState) {
        self.state = state;
        self.patches_since_rebuild = 0;
        self.join_churn = 0.0;
        self.metrics.gauge("incremental.version").set(self.state.version as i64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::BoundsPolicy;
    use crate::data::{Attr, Relation, Schema, Value};
    use crate::incremental::apply_to_db;
    use crate::util::testkit::assert_close;
    use crate::util::{FxHashMap, SplitMix64};

    /// Two-relation star with clusterable structure (mirrors rkmeans tests).
    fn setup(n_fact: usize, seed: u64) -> (Database, Feq) {
        let mut rng = SplitMix64::new(seed);
        let mut fact = Relation::new(
            "fact",
            Schema::new(vec![Attr::cat("item", 8), Attr::double("units")]),
        );
        for _ in 0..n_fact {
            let item = rng.below(8) as u32;
            let units = if item < 4 {
                (rng.uniform(0.0, 1.0) * 16.0).round() / 16.0
            } else {
                100.0 + (rng.uniform(0.0, 1.0) * 16.0).round() / 16.0
            };
            fact.push_row(&[Value::Cat(item), Value::Double(units)]);
        }
        let mut items =
            Relation::new("items", Schema::new(vec![Attr::cat("item", 8), Attr::double("price")]));
        for i in 0..8u32 {
            items.push_row(&[Value::Cat(i), Value::Double(if i < 4 { 1.0 } else { 50.0 })]);
        }
        let mut db = Database::new();
        db.add(fact);
        db.add(items);
        let feq = Feq::with_features(&["fact", "items"], &["item", "units", "price"]);
        (db, feq)
    }

    fn batch(rng: &mut SplitMix64, n: usize) -> Vec<TupleDelta> {
        (0..n)
            .map(|_| {
                let item = rng.below(8) as u32;
                let units = (rng.uniform(0.0, 2.0) * 16.0).round() / 16.0;
                TupleDelta::insert("fact", vec![Value::Cat(item), Value::Double(units)])
            })
            .collect()
    }

    fn lenient() -> PlannerOpts {
        PlannerOpts {
            drift_threshold: 1.1,
            max_patch_fraction: 1.0,
            rebuild_every: 0,
            max_join_churn: f64::INFINITY,
            ..PlannerOpts::default()
        }
    }

    #[test]
    fn patched_grid_matches_rebuild_grid() {
        let (mut db, feq) = setup(300, 1);
        let rk = RkConfig::new(4);
        let mut engine =
            IncrementalEngine::new(&db, feq.clone(), rk.clone(), lenient(), Metrics::new())
                .unwrap();
        let mut rng = SplitMix64::new(7);
        for round in 0..4 {
            let deltas = batch(&mut rng, 20);
            apply_to_db(&mut db, &deltas).unwrap();
            let (decision, result) = engine.apply_batch(&db, &deltas).unwrap();
            assert_eq!(decision, PlanDecision::Patched, "round {round}");
            // The patched grid must be exactly the grid a full pipeline
            // computes on the updated database with the same (frozen)
            // Step-2 models — compare against an engine-independent run.
            let tree = Hypergraph::from_feq(&db, &feq).join_tree().unwrap();
            let scratch = {
                let mut assigners: FxHashMap<String, Box<dyn GidAssigner + '_>> =
                    FxHashMap::default();
                for m in &result.models {
                    assigners.insert(m.name.clone(), Box::new(m));
                }
                crate::faq::grid_weights(&db, &feq, &tree, &assigners).unwrap()
            };
            assert_eq!(result.grid_points, scratch.len(), "round {round}");
            assert_close(result.grid_mass, scratch.mass(), 1e-9);
            assert!(result.objective_grid.is_finite() && result.objective_grid >= 0.0);
        }
        assert_eq!(engine.version(), 5); // init + 4 patches
    }

    #[test]
    fn deletes_patch_through() {
        let (mut db, feq) = setup(200, 2);
        let rk = RkConfig::new(3);
        let mut engine =
            IncrementalEngine::new(&db, feq, rk, lenient(), Metrics::new()).unwrap();
        let before = engine.result().grid_mass;
        // Delete five concrete fact rows.
        let fact = db.get("fact").unwrap();
        let deltas: Vec<TupleDelta> =
            (0..5).map(|r| TupleDelta::delete("fact", fact.row(r))).collect();
        apply_to_db(&mut db, &deltas).unwrap();
        let (decision, result) = engine.apply_batch(&db, &deltas).unwrap();
        assert_eq!(decision, PlanDecision::Patched);
        assert_close(result.grid_mass, before - 5.0, 1e-9);
    }

    #[test]
    fn drift_triggers_rebuild() {
        let (mut db, feq) = setup(150, 3);
        let rk = RkConfig::new(3);
        let opts = PlannerOpts { drift_threshold: 0.10, ..lenient() };
        let metrics = Metrics::new();
        let mut engine = IncrementalEngine::new(&db, feq, rk, opts, metrics.clone()).unwrap();
        // Pour most of the new mass onto one previously-light item.
        let deltas: Vec<TupleDelta> = (0..120)
            .map(|_| TupleDelta::insert("fact", vec![Value::Cat(7), Value::Double(0.5)]))
            .collect();
        apply_to_db(&mut db, &deltas).unwrap();
        let (decision, _) = engine.apply_batch(&db, &deltas).unwrap();
        assert!(
            matches!(decision, PlanDecision::Rebuilt(RebuildReason::Drift(_))),
            "expected drift rebuild, got {decision:?}"
        );
        assert_eq!(metrics.counter("incremental.rebuilds_drift").get(), 1);
        // After rebaselining, an ordinary small batch patches again.
        let mut rng = SplitMix64::new(11);
        let small = batch(&mut rng, 5);
        apply_to_db(&mut db, &small).unwrap();
        let (decision, _) = engine.apply_batch(&db, &small).unwrap();
        assert_eq!(decision, PlanDecision::Patched);
    }

    #[test]
    fn oversized_batch_triggers_rebuild() {
        let (mut db, feq) = setup(100, 4);
        let opts = PlannerOpts { max_patch_fraction: 0.01, ..lenient() };
        let mut engine =
            IncrementalEngine::new(&db, feq, RkConfig::new(2), opts, Metrics::new()).unwrap();
        let mut rng = SplitMix64::new(5);
        let deltas = batch(&mut rng, 50);
        apply_to_db(&mut db, &deltas).unwrap();
        let (decision, _) = engine.apply_batch(&db, &deltas).unwrap();
        assert_eq!(decision, PlanDecision::Rebuilt(RebuildReason::BatchTooLarge));
    }

    #[test]
    fn snapshot_restore_rolls_back_versions() {
        let (mut db, feq) = setup(200, 6);
        let mut engine =
            IncrementalEngine::new(&db, feq, RkConfig::new(3), lenient(), Metrics::new())
                .unwrap();
        let snap = engine.snapshot();
        let snap_db = db.clone();
        let mut rng = SplitMix64::new(13);
        let deltas = batch(&mut rng, 10);
        apply_to_db(&mut db, &deltas).unwrap();
        engine.apply_batch(&db, &deltas).unwrap();
        assert_eq!(engine.version(), snap.version + 1);

        // Roll both the engine and the database back, replay a different
        // batch: versions and results continue consistently.
        engine.restore(snap.clone());
        let mut db = snap_db;
        assert_eq!(engine.version(), snap.version);
        let deltas2 = batch(&mut rng, 7);
        apply_to_db(&mut db, &deltas2).unwrap();
        let (decision, result) = engine.apply_batch(&db, &deltas2).unwrap();
        assert_eq!(decision, PlanDecision::Patched);
        assert_close(result.grid_mass, snap.result.grid_mass + 7.0, 1e-9);
    }

    #[test]
    fn join_churn_triggers_rebuild() {
        let (mut db, feq) = setup(100, 9);
        // Every other trigger disabled; churn capped at 5% of the mass.
        let opts = PlannerOpts { max_join_churn: 0.05, ..lenient() };
        let mut engine =
            IncrementalEngine::new(&db, feq, RkConfig::new(2), opts, Metrics::new()).unwrap();
        let mut rng = SplitMix64::new(19);
        // First batch patches (churn starts at 0), accumulating churn 10
        // > 0.05·110; the next batch must rebuild.
        let b1 = batch(&mut rng, 10);
        apply_to_db(&mut db, &b1).unwrap();
        let (d1, _) = engine.apply_batch(&db, &b1).unwrap();
        assert_eq!(d1, PlanDecision::Patched);
        let b2 = batch(&mut rng, 2);
        apply_to_db(&mut db, &b2).unwrap();
        let (d2, _) = engine.apply_batch(&db, &b2).unwrap();
        assert_eq!(d2, PlanDecision::Rebuilt(RebuildReason::JoinChurn));
        // The rebuild reset the accumulator: small batches patch again.
        let b3 = batch(&mut rng, 2);
        apply_to_db(&mut db, &b3).unwrap();
        let (d3, _) = engine.apply_batch(&db, &b3).unwrap();
        assert_eq!(d3, PlanDecision::Patched);
    }

    #[test]
    fn bounds_policy_flows_through_patch_path_bitwise() {
        // The Step-4 engine policy is a pure throughput knob: a planner
        // configured with Elkan bounds must patch (warm-started Step 4
        // included) to bit-identical results as a Hamerly planner.
        let (mut db, feq) = setup(250, 12);
        let mut ham = IncrementalEngine::new(
            &db,
            feq.clone(),
            RkConfig::new(4).with_bounds(BoundsPolicy::Hamerly),
            lenient(),
            Metrics::new(),
        )
        .unwrap();
        let mut elk = IncrementalEngine::new(
            &db,
            feq,
            RkConfig::new(4).with_bounds(BoundsPolicy::Elkan),
            lenient(),
            Metrics::new(),
        )
        .unwrap();
        let mut rng = SplitMix64::new(31);
        for round in 0..3 {
            let deltas = batch(&mut rng, 15);
            apply_to_db(&mut db, &deltas).unwrap();
            let (d1, r1) = ham.apply_batch(&db, &deltas).unwrap();
            let (d2, r2) = elk.apply_batch(&db, &deltas).unwrap();
            assert_eq!(d1, PlanDecision::Patched, "round {round}");
            assert_eq!(d2, PlanDecision::Patched, "round {round}");
            assert_eq!(r1.objective_grid.to_bits(), r2.objective_grid.to_bits());
            assert_eq!(r1.grid_points, r2.grid_points);
        }
        assert_eq!(ham.result().step4_stats.bounds, "hamerly");
        assert_eq!(elk.result().step4_stats.bounds, "elkan");
    }

    #[test]
    fn carried_engine_state_matches_cold_warm_start_bitwise() {
        // The resumed Step 4 (carried assignments + bounds, spliced over
        // each batch's grid edit) is a pure throughput artifact: a
        // carry-enabled planner must publish bit-identical results to a
        // carry-disabled one, batch after batch, inserts and deletes.
        let (mut db, feq) = setup(250, 21);
        let rk = RkConfig::new(4);
        let m_carry = Metrics::new();
        let mut carry =
            IncrementalEngine::new(&db, feq.clone(), rk.clone(), lenient(), m_carry.clone())
                .unwrap();
        let cold_opts = PlannerOpts { carry_state: false, ..lenient() };
        let mut cold = IncrementalEngine::new(&db, feq, rk, cold_opts, Metrics::new()).unwrap();
        let mut rng = SplitMix64::new(77);
        for round in 0..4usize {
            let mut deltas = batch(&mut rng, 12);
            if round > 0 {
                // Mix in a delete so the splice log sees removals too.
                let row = db.get("fact").unwrap().row(round);
                deltas.push(TupleDelta::delete("fact", row));
            }
            apply_to_db(&mut db, &deltas).unwrap();
            let (d1, r1) = carry.apply_batch(&db, &deltas).unwrap();
            let (d2, r2) = cold.apply_batch(&db, &deltas).unwrap();
            assert_eq!(d1, PlanDecision::Patched, "round {round}");
            assert_eq!(d2, PlanDecision::Patched, "round {round}");
            crate::util::testkit::assert_bitwise_result(&r1, &r2, &format!("round {round}"));
        }
        // The carry arm actually resumed (bounds survived at least once).
        assert!(m_carry.counter("incremental.resumes").get() >= 1);
    }

    #[test]
    fn sharded_planner_matches_single_bitwise() {
        // `shards` is a pure throughput knob: a planner maintaining four
        // per-shard delta states (parallel patches, merged root, composed
        // splice log) must publish bit-identical results to the unsharded
        // planner, batch after batch, inserts and deletes, through a
        // forced rebuild.
        let (mut db, feq) = setup(250, 33);
        let rk = RkConfig::new(4);
        let metrics = Metrics::new();
        // Both engines rebuild on the same schedule (round 3), so the
        // comparison also covers a sharded rebuild against an unsharded
        // one — only the `shards` knob differs.
        let single_opts = PlannerOpts { rebuild_every: 3, ..lenient() };
        let mut one =
            IncrementalEngine::new(&db, feq.clone(), rk.clone(), single_opts, Metrics::new())
                .unwrap();
        let sharded_opts = PlannerOpts { shards: 4, rebuild_every: 3, ..lenient() };
        let mut four =
            IncrementalEngine::new(&db, feq, rk, sharded_opts, metrics.clone()).unwrap();
        assert_eq!(metrics.gauge("incremental.shards").get(), 4);
        let mut rng = SplitMix64::new(41);
        for round in 0..4usize {
            let mut deltas = batch(&mut rng, 10);
            if round > 0 {
                let row = db.get("fact").unwrap().row(round);
                deltas.push(TupleDelta::delete("fact", row));
            }
            apply_to_db(&mut db, &deltas).unwrap();
            let (d1, r1) = one.apply_batch(&db, &deltas).unwrap();
            let (_, r2) = four.apply_batch(&db, &deltas).unwrap();
            if round < 3 {
                assert_eq!(d1, PlanDecision::Patched, "round {round}");
            }
            crate::util::testkit::assert_bitwise_result(&r1, &r2, &format!("round {round}"));
        }
        // Round 3 hit the sharded planner's rebuild schedule, so both the
        // patch path and the sharded rebuild path were exercised.
        assert_eq!(metrics.counter("incremental.rebuilds_schedule").get(), 1);
    }

    #[test]
    fn cost_model_crossover_forces_both_regimes() {
        // The static size threshold is set so tight that *every* batch
        // would rebuild under it; with the cost model on and both
        // estimates seeded, the learned crossover decides instead.
        let (mut db, feq) = setup(200, 14);
        let opts = PlannerOpts { cost_model: true, max_patch_fraction: 1e-9, ..lenient() };
        let metrics = Metrics::new();
        let mut engine =
            IncrementalEngine::new(&db, feq, RkConfig::new(3), opts, metrics.clone()).unwrap();
        let mut rng = SplitMix64::new(3);

        // Regime 1: patches predicted ruinous (1 s per delta vs a 1 µs
        // rebuild) — the batch must rebuild, attributed to the model.
        engine.seed_cost_estimates(1.0, 1e-6);
        let b1 = batch(&mut rng, 4);
        apply_to_db(&mut db, &b1).unwrap();
        let (d1, _) = engine.apply_batch(&db, &b1).unwrap();
        assert_eq!(d1, PlanDecision::Rebuilt(RebuildReason::CostModel));
        assert_eq!(metrics.counter("incremental.rebuilds_cost").get(), 1);

        // Regime 2: patches predicted near-free — must patch even though
        // the batch dwarfs max_patch_fraction·|D| (the learned crossover
        // supersedes the static check while both estimates exist).
        engine.seed_cost_estimates(1e-12, 1e3);
        let b2 = batch(&mut rng, 6);
        apply_to_db(&mut db, &b2).unwrap();
        let (d2, _) = engine.apply_batch(&db, &b2).unwrap();
        assert_eq!(d2, PlanDecision::Patched);

        // Deterministic tie-break: equal predicted costs patch.
        engine.seed_cost_estimates(1.0, 2.0);
        let b3 = batch(&mut rng, 2); // 2 deltas × 1.0 == 2.0 — a tie
        apply_to_db(&mut db, &b3).unwrap();
        let (d3, _) = engine.apply_batch(&db, &b3).unwrap();
        assert_eq!(d3, PlanDecision::Patched);
    }

    #[test]
    fn spill_budget_planner_matches_unspilled_bitwise() {
        // The spill budget is a residency knob: a planner spilling all
        // but two message tables per state must publish bit-identical
        // results to the unspilled planner, batch after batch.
        let (mut db, feq) = setup(250, 15);
        let rk = RkConfig::new(4);
        let metrics = Metrics::new();
        let mut plain =
            IncrementalEngine::new(&db, feq.clone(), rk.clone(), lenient(), Metrics::new())
                .unwrap();
        let spill_opts = PlannerOpts { spill_budget: 2, ..lenient() };
        let mut spilly =
            IncrementalEngine::new(&db, feq, rk, spill_opts, metrics.clone()).unwrap();
        let mut rng = SplitMix64::new(51);
        for round in 0..4usize {
            let mut deltas = batch(&mut rng, 12);
            if round > 0 {
                let row = db.get("fact").unwrap().row(round);
                deltas.push(TupleDelta::delete("fact", row));
            }
            apply_to_db(&mut db, &deltas).unwrap();
            let (d1, r1) = plain.apply_batch(&db, &deltas).unwrap();
            let (d2, r2) = spilly.apply_batch(&db, &deltas).unwrap();
            assert_eq!(d1, PlanDecision::Patched, "round {round}");
            assert_eq!(d2, PlanDecision::Patched, "round {round}");
            crate::util::testkit::assert_bitwise_result(&r1, &r2, &format!("round {round}"));
        }
        assert!(
            metrics.gauge("incremental.spill_spilled").get() > 0,
            "budget 2 must actually force spills"
        );
    }

    #[test]
    fn snapshot_ships_as_serving_model() {
        let (mut db, feq) = setup(150, 10);
        let mut engine =
            IncrementalEngine::new(&db, feq, RkConfig::new(3), lenient(), Metrics::new())
                .unwrap();
        let mut rng = SplitMix64::new(23);
        let deltas = batch(&mut rng, 6);
        apply_to_db(&mut db, &deltas).unwrap();
        engine.apply_batch(&db, &deltas).unwrap();

        // Writer snapshots a version, ships bytes; the replica serves it
        // without ever seeing the database or the delta state.
        let model = engine.model();
        assert_eq!(model.version, engine.version());
        let replica = RkModel::from_bytes(&model.to_bytes()).unwrap();
        assert_eq!(replica.version, engine.version());
        assert_eq!(replica.k(), model.k());
        for vals in [
            vec![Value::Cat(1), Value::Double(0.5), Value::Double(1.0)],
            vec![Value::Cat(6), Value::Double(100.25), Value::Double(50.0)],
        ] {
            assert_eq!(model.assign(&vals), replica.assign(&vals));
        }
    }

    #[test]
    fn scheduled_rebuild_fires() {
        let (mut db, feq) = setup(120, 8);
        let opts = PlannerOpts { rebuild_every: 2, ..lenient() };
        let mut engine =
            IncrementalEngine::new(&db, feq, RkConfig::new(2), opts, Metrics::new()).unwrap();
        let mut rng = SplitMix64::new(17);
        let mut decisions = Vec::new();
        for _ in 0..3 {
            let deltas = batch(&mut rng, 4);
            apply_to_db(&mut db, &deltas).unwrap();
            let (d, _) = engine.apply_batch(&db, &deltas).unwrap();
            decisions.push(d);
        }
        assert_eq!(decisions[0], PlanDecision::Patched);
        assert_eq!(decisions[1], PlanDecision::Patched);
        assert_eq!(decisions[2], PlanDecision::Rebuilt(RebuildReason::Schedule));
    }
}
