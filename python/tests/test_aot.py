"""AOT lowering sanity: HLO text is produced, is parseable-looking, and the
manifest describes it accurately."""

import json
import subprocess
import sys
from pathlib import Path

from compile import aot


def test_lower_step_produces_hlo_text():
    text = aot.lower_step(128, 4, 2)
    assert "HloModule" in text
    assert "ENTRY" in text
    # The kernel's dot contraction must survive lowering.
    assert "dot(" in text or "dot." in text


def test_lower_sweep_produces_hlo_text():
    text = aot.lower_sweep(128, 4, 2, 2)
    assert "HloModule" in text
    # A scan lowers to a while loop in HLO.
    assert "while" in text


def test_cli_quick_writes_manifest(tmp_path):
    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out), "--quick"],
        check=True,
        cwd=str(Path(__file__).resolve().parents[1]),
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["version"] == 1
    assert manifest["block_n"] >= 8
    arts = manifest["artifacts"]
    assert len(arts) == 1
    entry = arts[0]
    f = out / entry["file"]
    assert f.exists() and f.stat().st_size > 1000
    assert entry["entry"] == "lloyd_step"
    assert entry["n"] % manifest["block_n"] == 0


def test_buckets_are_block_aligned():
    from compile.kernels import lloyd as kernels

    for n, d, k in aot.BUCKETS:
        assert n % kernels.BLOCK_N == 0
        assert d > 0 and k > 0
        # Every bucket fits a 16 MiB VMEM budget.
        assert kernels.vmem_bytes(kernels.BLOCK_N, d, k) < 16 * 1024 * 1024
