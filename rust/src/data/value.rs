//! Scalar values and their join-key encoding.

/// Dictionary-encoded categorical id.
pub type CatId = u32;

/// A single attribute value.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Value {
    /// Integer-valued attribute (usable as a join key).
    Int(i64),
    /// Continuous attribute. Never used as a join key.
    Double(f64),
    /// Dictionary-encoded categorical attribute (usable as a join key).
    Cat(CatId),
}

impl Value {
    /// Encode as a `u64` join/hash key. Panics on `Double`: continuous
    /// attributes are payload features, never join keys — attempting to
    /// join on one is a schema bug we want to fail loudly on.
    #[inline]
    pub fn key_u64(&self) -> u64 {
        match self {
            Value::Int(v) => *v as u64,
            Value::Cat(c) => *c as u64,
            Value::Double(_) => panic!("continuous attribute used as a join key"),
        }
    }

    /// Numeric view (categorical ids cast to their code; used for display
    /// and for the dense one-hot embedding path).
    #[inline]
    pub fn as_f64(&self) -> f64 {
        match self {
            Value::Int(v) => *v as f64,
            Value::Double(v) => *v,
            Value::Cat(c) => *c as f64,
        }
    }

    /// The categorical id, if categorical.
    #[inline]
    pub fn as_cat(&self) -> Option<CatId> {
        match self {
            Value::Cat(c) => Some(*c),
            _ => None,
        }
    }
}

impl std::fmt::Display for Value {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Value::Int(v) => write!(f, "{v}"),
            Value::Double(v) => write!(f, "{v}"),
            Value::Cat(c) => write!(f, "#{c}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key_encoding_roundtrips_ints() {
        assert_eq!(Value::Int(-1).key_u64(), u64::MAX);
        assert_eq!(Value::Int(5).key_u64(), 5);
        assert_eq!(Value::Cat(7).key_u64(), 7);
    }

    #[test]
    #[should_panic(expected = "join key")]
    fn double_key_panics() {
        let _ = Value::Double(1.5).key_u64();
    }

    #[test]
    fn as_f64_views() {
        assert_eq!(Value::Int(3).as_f64(), 3.0);
        assert_eq!(Value::Double(2.5).as_f64(), 2.5);
        assert_eq!(Value::Cat(4).as_f64(), 4.0);
        assert_eq!(Value::Cat(4).as_cat(), Some(4));
        assert_eq!(Value::Int(4).as_cat(), None);
    }
}
