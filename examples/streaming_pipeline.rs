//! Streaming ingestion + periodic re-clustering through the coordinator.
//!
//! ```sh
//! cargo run --release --offline --example streaming_pipeline
//! ```
//!
//! Simulates a Favorita-style deployment: sales tuples stream into the
//! fact table through a bounded (backpressured) channel while the
//! coordinator re-runs Rk-means every `RECLUSTER_EVERY` tuples and
//! publishes versioned clusterings. Because Rk-means only touches base
//! relations, each re-cluster is Õ(|D|) — no join is ever materialized.

use rkmeans::coordinator::{Coordinator, CoordinatorConfig};
use rkmeans::data::Value;
use rkmeans::rkmeans::RkConfig;
use rkmeans::synthetic::{favorita, Scale};
use rkmeans::util::SplitMix64;
use std::time::Duration;

const RECLUSTER_EVERY: usize = 3_000;
const BATCHES: usize = 4;

fn main() -> anyhow::Result<()> {
    let db = favorita::generate(Scale::small(), 7);
    let feq = favorita::feq();
    let sales_schema = db.get("sales").expect("sales relation").schema.clone();
    let n_dates = sales_schema.attr(0).domain as u64;
    let n_stores = sales_schema.attr(1).domain as u64;
    let n_items = sales_schema.attr(2).domain as u64;
    println!(
        "streaming into Favorita: {} base tuples, reclustering every {} new sales",
        db.total_rows(),
        RECLUSTER_EVERY
    );

    let mut cfg = CoordinatorConfig::new(RkConfig::new(8));
    cfg.recluster_every = RECLUSTER_EVERY;
    cfg.channel_capacity = 512; // small queue: demonstrates backpressure
    let coord = Coordinator::start(db, feq, cfg);

    // Producer: a new day of skewed sales per batch.
    let mut rng = SplitMix64::new(99);
    for batch in 0..BATCHES {
        for _ in 0..RECLUSTER_EVERY {
            let item = rng.below(n_items);
            let units = ((2.0 + rng.normal()).exp() * 100.0).round() / 100.0;
            coord.insert(
                "sales",
                vec![
                    Value::Cat(rng.below(n_dates) as u32),
                    Value::Cat(rng.below(n_stores) as u32),
                    Value::Cat(item as u32),
                    Value::Double(units),
                    Value::Cat(u32::from(rng.coin(0.08))),
                ],
            )?; // blocks if the coordinator is behind (backpressure)
        }
        match coord.recv_update(Duration::from_secs(300)) {
            Some(u) => println!(
                "update v{} after {:>6} tuples: |G|={:<7} objective={:.4e}  (job {:?})",
                u.version, u.ingested, u.result.grid_points, u.result.objective_grid, u.elapsed
            ),
            None => println!("batch {batch}: no update within timeout"),
        }
    }

    println!("\n-- coordinator metrics --\n{}", coord.metrics().render());
    let final_db = coord.shutdown()?;
    println!(
        "final sales table: {} rows",
        final_db.get("sales").expect("sales relation").n_rows()
    );
    Ok(())
}
