//! Closed-form optimal weighted k-means in a categorical subspace
//! (paper §4.1, Proposition 4.1 / Corollary 4.3 / Theorem 4.4).
//!
//! For one-hot encoded categories with marginal weights `v`, the optimal
//! κ-clustering puts each of the κ−1 heaviest categories in its own
//! (singleton) cluster and all remaining "light" categories together. The
//! optimal cost is `‖v‖₁ − Σ_heavy v_e − ‖v_light‖₂²/‖v_light‖₁`.
//!
//! The light-cluster centroid is the weight-normalized vector over light
//! categories (Eq. 36); crucially its support is disjoint from every heavy
//! singleton, so the κ component vectors are *mutually orthogonal* — the
//! fact [`sparse_lloyd`](crate::cluster::sparse_lloyd) exploits for O(1)
//! distances.

use crate::util::FxHashMap;

/// Optimal categorical clustering for one subspace.
#[derive(Clone, Debug)]
pub struct CatClusters {
    /// Heavy category keys, descending by weight (each its own cluster).
    pub heavy: Vec<u64>,
    /// Heavy category weights (parallel to `heavy`).
    pub heavy_w: Vec<f64>,
    /// Light categories and weights (one shared cluster); may be empty.
    pub light: Vec<(u64, f64)>,
    /// `‖v_light‖₁`.
    pub light_mass: f64,
    /// `‖v_light‖₂²`.
    pub light_sq: f64,
    /// Optimal weighted k-means cost in this subspace (unit one-hot scale).
    pub cost: f64,
    heavy_index: FxHashMap<u64, u32>,
}

impl CatClusters {
    /// Number of clusters actually produced (≤ requested κ; smaller when
    /// the domain has fewer categories).
    pub fn kappa(&self) -> usize {
        self.heavy.len() + usize::from(!self.light.is_empty())
    }

    /// True if a light (merged) cluster exists.
    pub fn has_light(&self) -> bool {
        !self.light.is_empty()
    }

    /// Cluster id of the light cluster (only meaningful if `has_light`).
    pub fn light_gid(&self) -> u32 {
        self.heavy.len() as u32
    }

    /// Cluster id for a category key: its singleton if heavy, else light.
    /// Unseen keys (zero marginal weight) also map to the light cluster —
    /// they are distance-√2 from every component, so the tie is harmless.
    pub fn gid(&self, key: u64) -> u32 {
        match self.heavy_index.get(&key) {
            Some(&i) => i,
            None => self.light_gid().min(self.kappa().saturating_sub(1) as u32),
        }
    }

    /// Squared norm `‖u_a‖²` of component `a`'s centroid vector:
    /// 1 for heavy singletons, `‖v_light‖₂²/‖v_light‖₁²` for the light
    /// centroid.
    pub fn component_norm_sq(&self, gid: u32) -> f64 {
        if (gid as usize) < self.heavy.len() {
            1.0
        } else {
            debug_assert!(self.has_light());
            self.light_sq / (self.light_mass * self.light_mass)
        }
    }

    /// The light centroid's coordinate for a category key (0 if not light).
    pub fn light_coord(&self, key: u64) -> f64 {
        if self.light_mass == 0.0 {
            return 0.0;
        }
        self.light
            .iter()
            .find(|(e, _)| *e == key)
            .map(|(_, w)| w / self.light_mass)
            .unwrap_or(0.0)
    }
}

impl CatClusters {
    /// Reassemble a clustering from its serialized parts (the
    /// [`RkModel`](crate::rkmeans::RkModel) byte format stores `heavy`,
    /// `heavy_w`, `light` and `cost`); the light-cluster mass/norm and the
    /// heavy index are derived, so the reconstruction assigns and scores
    /// identically to the original.
    pub fn from_parts(
        heavy: Vec<u64>,
        heavy_w: Vec<f64>,
        light: Vec<(u64, f64)>,
        cost: f64,
    ) -> CatClusters {
        let light_mass: f64 = light.iter().map(|&(_, w)| w).sum();
        let light_sq: f64 = light.iter().map(|&(_, w)| w * w).sum();
        let heavy_index: FxHashMap<u64, u32> =
            heavy.iter().enumerate().map(|(i, &e)| (e, i as u32)).collect();
        CatClusters { heavy, heavy_w, light, light_mass, light_sq, cost, heavy_index }
    }
}

/// Compute the optimal categorical κ-clustering from a marginal weight
/// table `(category key, weight)` (Theorem 4.4).
pub fn categorical_kmeans(marginal: &[(u64, f64)], kappa: usize) -> CatClusters {
    assert!(kappa >= 1, "kappa must be positive");
    let mut sorted: Vec<(u64, f64)> =
        marginal.iter().copied().filter(|&(_, w)| w > 0.0).collect();
    // Descending weight; ties broken by key for determinism.
    sorted.sort_by(|a, b| {
        b.1.partial_cmp(&a.1)
            .expect("finite weights")
            .then(a.0.cmp(&b.0))
    });
    let total: f64 = sorted.iter().map(|&(_, w)| w).sum();

    let n_heavy = if sorted.len() <= kappa {
        sorted.len() // every category its own cluster, no light cluster
    } else {
        kappa - 1
    };
    let heavy: Vec<u64> = sorted[..n_heavy].iter().map(|&(e, _)| e).collect();
    let heavy_w: Vec<f64> = sorted[..n_heavy].iter().map(|&(_, w)| w).collect();
    let light: Vec<(u64, f64)> = sorted[n_heavy..].to_vec();
    let light_mass: f64 = light.iter().map(|&(_, w)| w).sum();
    let light_sq: f64 = light.iter().map(|&(_, w)| w * w).sum();

    // OPT = ‖v‖₁ − Σ_heavy v_e − ‖v_light‖₂²/‖v_light‖₁ (Prop 4.1 + Cor 4.3).
    let heavy_sum: f64 = heavy_w.iter().sum();
    let cost = if light_mass > 0.0 {
        (total - heavy_sum - light_sq / light_mass).max(0.0)
    } else {
        0.0
    };

    let heavy_index: FxHashMap<u64, u32> =
        heavy.iter().enumerate().map(|(i, &e)| (e, i as u32)).collect();

    CatClusters { heavy, heavy_w, light, light_mass, light_sq, cost, heavy_index }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::{assert_close, for_cases};
    use crate::util::SplitMix64;

    /// Cost of an arbitrary partition of categories (for the optimality
    /// property test): Σ_F ‖v_F‖₁ − ‖v_F‖₂²/‖v_F‖₁  (Prop 4.1).
    fn partition_cost(weights: &FxHashMap<u64, f64>, parts: &[Vec<u64>]) -> f64 {
        let mut cost = 0.0;
        for part in parts {
            let l1: f64 = part.iter().map(|e| weights[e]).sum();
            let l2: f64 = part.iter().map(|e| weights[e] * weights[e]).sum();
            if l1 > 0.0 {
                cost += l1 - l2 / l1;
            }
        }
        cost
    }

    #[test]
    fn heavy_light_split() {
        let marginal = vec![(10, 5.0), (20, 3.0), (30, 1.0), (40, 1.0)];
        let c = categorical_kmeans(&marginal, 3);
        assert_eq!(c.heavy, vec![10, 20]);
        assert_eq!(c.light.len(), 2);
        assert_close(c.light_mass, 2.0, 1e-12);
        assert_close(c.light_sq, 2.0, 1e-12);
        // cost = 10 - 8 - 2/2 = 1.
        assert_close(c.cost, 1.0, 1e-12);
        assert_eq!(c.gid(10), 0);
        assert_eq!(c.gid(20), 1);
        assert_eq!(c.gid(30), 2);
        assert_eq!(c.gid(40), 2);
        assert_eq!(c.kappa(), 3);
    }

    #[test]
    fn small_domain_all_singletons() {
        let marginal = vec![(1, 2.0), (2, 1.0)];
        let c = categorical_kmeans(&marginal, 5);
        assert_eq!(c.kappa(), 2);
        assert!(!c.has_light());
        assert_eq!(c.cost, 0.0);
        assert_eq!(c.gid(1), 0);
        // Unseen key maps to last cluster without panicking.
        assert!(c.gid(99) < 2);
    }

    #[test]
    fn kappa_one_merges_everything() {
        let marginal = vec![(1, 3.0), (2, 2.0), (3, 1.0)];
        let c = categorical_kmeans(&marginal, 1);
        assert!(c.heavy.is_empty());
        assert_eq!(c.light.len(), 3);
        // cost = 6 - 14/6.
        assert_close(c.cost, 6.0 - 14.0 / 6.0, 1e-12);
        assert_eq!(c.gid(1), 0);
        assert_eq!(c.kappa(), 1);
    }

    #[test]
    fn component_norms() {
        let marginal = vec![(1, 4.0), (2, 2.0), (3, 2.0)];
        let c = categorical_kmeans(&marginal, 2);
        assert_close(c.component_norm_sq(0), 1.0, 1e-12);
        // light = {2,3}: ‖·‖² = (4+4)/16 = 0.5.
        assert_close(c.component_norm_sq(1), 0.5, 1e-12);
        assert_close(c.light_coord(2), 0.5, 1e-12);
        assert_close(c.light_coord(1), 0.0, 1e-12);
    }

    #[test]
    fn optimal_beats_random_partitions() {
        // Theorem 4.4: the heavy/light split is optimal. Compare against
        // random κ-partitions of the domain.
        for_cases(30, |rng| {
            let l = 3 + rng.below(8) as usize;
            let kappa = 2 + rng.below(3.min(l as u64 - 1)) as usize;
            let wlist: Vec<(u64, f64)> =
                (0..l).map(|e| (e as u64, rng.uniform(0.1, 5.0))).collect();
            let wmap: FxHashMap<u64, f64> = wlist.iter().copied().collect();
            let opt = categorical_kmeans(&wlist, kappa);

            // Random partition into exactly kappa non-empty parts.
            let mut rng2 = SplitMix64::new(rng.next_u64());
            let mut parts: Vec<Vec<u64>> = vec![Vec::new(); kappa];
            let mut keys: Vec<u64> = wlist.iter().map(|&(e, _)| e).collect();
            rng2.shuffle(&mut keys);
            for (i, &e) in keys.iter().enumerate() {
                if i < kappa {
                    parts[i].push(e);
                } else {
                    parts[rng2.below(kappa as u64) as usize].push(e);
                }
            }
            let rand_cost = partition_cost(&wmap, &parts);
            assert!(
                opt.cost <= rand_cost + 1e-9,
                "optimal {} beat by random partition {}",
                opt.cost,
                rand_cost
            );
        });
    }

    #[test]
    fn from_parts_reconstructs_identically() {
        let marginal = vec![(10u64, 5.0), (20, 3.0), (30, 1.0), (40, 1.0)];
        let c = categorical_kmeans(&marginal, 3);
        let r = CatClusters::from_parts(
            c.heavy.clone(),
            c.heavy_w.clone(),
            c.light.clone(),
            c.cost,
        );
        assert_close(r.light_mass, c.light_mass, 1e-12);
        assert_close(r.light_sq, c.light_sq, 1e-12);
        assert_eq!(r.kappa(), c.kappa());
        for key in [10u64, 20, 30, 40, 99] {
            assert_eq!(r.gid(key), c.gid(key), "key {key}");
            assert_close(r.light_coord(key), c.light_coord(key), 1e-12);
        }
        for g in 0..c.kappa() as u32 {
            assert_close(r.component_norm_sq(g), c.component_norm_sq(g), 1e-12);
        }
    }

    #[test]
    fn cost_matches_partition_formula() {
        let wlist = vec![(0u64, 3.0), (1, 2.5), (2, 1.0), (3, 0.5)];
        let wmap: FxHashMap<u64, f64> = wlist.iter().copied().collect();
        let c = categorical_kmeans(&wlist, 3);
        let parts = vec![vec![0], vec![1], vec![2, 3]];
        assert_close(c.cost, partition_cost(&wmap, &parts), 1e-12);
    }

    #[test]
    fn zero_weights_are_dropped() {
        let c = categorical_kmeans(&[(1, 0.0), (2, 1.0)], 2);
        assert_eq!(c.kappa(), 1);
        assert_eq!(c.heavy, vec![2]);
    }
}
